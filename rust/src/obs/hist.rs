//! Fixed-capacity, lock-free, log-scaled-bucket histogram.
//!
//! The layout is HDR-style with a linear head and 4 sub-buckets per
//! octave above it:
//!
//! * values `0..8` get one exact bucket each (indices `0..8`) — the
//!   regime where nanosecond deltas and small row counts live;
//! * every octave `[2^m, 2^(m+1))` for `m >= 3` splits into 4 equal
//!   sub-buckets (`4 * 61` indices), bounding the relative quantile
//!   error at ~12.5% across the full `u64` range.
//!
//! That is [`BUCKETS`] `= 252` fixed `AtomicU64` slots: [`Hist::new`] is
//! `const` (usable in `static` registries), [`Hist::record`] is a bucket
//! index computation plus three `Relaxed` `fetch_add`s — no locks, no
//! allocation, no ordering dependence — and a snapshot is a stack copy.
//! Enrolled in `cargo xtask lint`'s `no_alloc` rule via the `Hist::*`
//! wildcard root in `lint.toml`.
//!
//! Quantile estimates come bracketed: [`HistSnapshot::quantile`] returns
//! the `(lo, hi)` bounds of the bucket holding the rank, so
//! `lo <= true quantile <= hi` is a provable property (see the tests).

use std::sync::atomic::{AtomicU64, Ordering};

/// Values below this get one exact bucket each.
const LINEAR: u64 = 8;
/// Sub-buckets per octave above the linear head.
const SUBS: usize = 4;
/// Total bucket count: 8 linear + 4 sub-buckets × 61 octaves (msb 3..=63).
pub const BUCKETS: usize = LINEAR as usize + SUBS * 61;

/// A preallocated log-scaled histogram over `u64` samples (typically
/// nanoseconds or row counts). All methods are lock-free and alloc-free.
pub struct Hist {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Hist {
    /// An empty histogram. `const` so registries can live in `static`s
    /// with zero startup cost.
    pub const fn new() -> Self {
        // a const item as the repeat operand keeps this on MSRV 1.75
        // (inline-const array repeat needs 1.79)
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Hist {
            buckets: [ZERO; BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one sample. Lock-free: three `Relaxed` `fetch_add`s. A
    /// concurrent [`Hist::snapshot`] may observe the count and the bucket
    /// increments independently (the snapshot is not atomic across
    /// fields), but no increment is ever lost.
    pub fn record(&self, v: u64) {
        let idx = Self::bucket_index(v);
        // bucket_index() < BUCKETS for every u64 (property-tested);
        // `get` keeps the record path panic-free regardless
        if let Some(b) = self.buckets.get(idx) {
            b.fetch_add(1, Ordering::Relaxed);
        }
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Bucket index for a value: identity below [`LINEAR`], then
    /// `8 + 4*(msb-3) + sub` where `sub` is the top-two-bits-after-msb.
    /// Monotone in `v`, total over `u64`, and always `< BUCKETS`.
    pub const fn bucket_index(v: u64) -> usize {
        if v < LINEAR {
            return v as usize;
        }
        let msb = 63 - v.leading_zeros() as usize; // >= 3 since v >= 8
        let sub = ((v >> (msb - 2)) - 4) as usize; // 0..4 within the octave
        LINEAR as usize + (msb - 3) * SUBS + sub
    }

    /// Inclusive `(lo, hi)` value bounds of bucket `idx`. The buckets
    /// tile `u64`: `bounds(0).0 == 0`, `bounds(BUCKETS-1).1 == u64::MAX`,
    /// and each bucket starts one past the previous bucket's end.
    pub const fn bucket_bounds(idx: usize) -> (u64, u64) {
        if idx < LINEAR as usize {
            return (idx as u64, idx as u64);
        }
        let octave = (idx - LINEAR as usize) / SUBS;
        let sub = ((idx - LINEAR as usize) % SUBS) as u64;
        let msb = octave + 3;
        let width = 1u64 << (msb - 2);
        let lo = (4 + sub) << (msb - 2);
        (lo, lo + (width - 1))
    }

    /// Copy the current bucket counts into a stack snapshot. Not atomic
    /// across buckets (concurrent records may straddle the copy) but
    /// each bucket value is itself consistent.
    pub fn snapshot(&self) -> HistSnapshot {
        let mut buckets = [0u64; BUCKETS];
        for (dst, src) in buckets.iter_mut().zip(self.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistSnapshot {
            buckets,
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

/// A point-in-time copy of a [`Hist`]: plain `u64`s, free to inspect.
pub struct HistSnapshot {
    /// Per-bucket sample counts (see [`Hist::bucket_bounds`]).
    pub buckets: [u64; BUCKETS],
    /// Total samples recorded.
    pub count: u64,
    /// Sum of all recorded values (wrapping on overflow).
    pub sum: u64,
}

impl HistSnapshot {
    /// Bracketed quantile estimate: the inclusive `(lo, hi)` bounds of
    /// the bucket containing the rank-`ceil(q * count)` smallest sample,
    /// so `lo <= true quantile <= hi`. Returns `(0, 0)` when empty.
    pub fn quantile(&self, q: f64) -> (u64, u64) {
        if self.count == 0 {
            return (0, 0);
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            seen = seen.saturating_add(c);
            if seen >= rank {
                return Hist::bucket_bounds(idx);
            }
        }
        Hist::bucket_bounds(BUCKETS - 1)
    }

    /// Cumulative count of samples `<= bound` where `bound = 2^m - 1`
    /// (an octave edge, `m` in `3..=63`). These are exactly the `le`
    /// boundaries the Prometheus exposition emits, chosen so the
    /// cumulative sum is a whole-bucket prefix.
    pub fn cumulative_at_octave(&self, m: u32) -> u64 {
        let cut = LINEAR as usize + SUBS * (m as usize - 3);
        let mut total = 0u64;
        for &c in self.buckets.iter().take(cut) {
            total = total.saturating_add(c);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn bounds_are_monotone_and_tile_u64() {
        // contiguity: each bucket starts one past the previous end
        let (lo0, _) = Hist::bucket_bounds(0);
        assert_eq!(lo0, 0);
        for idx in 0..BUCKETS {
            let (lo, hi) = Hist::bucket_bounds(idx);
            assert!(lo <= hi, "idx {idx}: lo {lo} > hi {hi}");
            if idx + 1 < BUCKETS {
                let (next_lo, _) = Hist::bucket_bounds(idx + 1);
                assert_eq!(next_lo, hi + 1, "gap/overlap after idx {idx}");
            }
        }
        let (_, top) = Hist::bucket_bounds(BUCKETS - 1);
        assert_eq!(top, u64::MAX, "buckets must cover all of u64");
    }

    #[test]
    fn every_value_lands_in_exactly_one_bucket() {
        // contiguous monotone bounds + index/bounds agreement on random
        // values over every scale => exactly-one-bucket for all u64
        forall("hist index within bounds", 512, |g| {
            let shift = g.usize_in(0..=63);
            let v = g.rng().next_u64() >> shift;
            let idx = Hist::bucket_index(v);
            if idx >= BUCKETS {
                return false;
            }
            let (lo, hi) = Hist::bucket_bounds(idx);
            lo <= v && v <= hi
        });
        // edges the random sweep could miss
        for v in [0u64, 7, 8, 9, 15, 16, u64::MAX - 1, u64::MAX] {
            let idx = Hist::bucket_index(v);
            let (lo, hi) = Hist::bucket_bounds(idx);
            assert!(lo <= v && v <= hi, "v={v} idx={idx} [{lo},{hi}]");
        }
    }

    #[test]
    fn index_is_monotone_at_every_bucket_edge() {
        for idx in 0..BUCKETS {
            let (lo, hi) = Hist::bucket_bounds(idx);
            assert_eq!(Hist::bucket_index(lo), idx);
            assert_eq!(Hist::bucket_index(hi), idx);
            if hi < u64::MAX {
                assert_eq!(Hist::bucket_index(hi + 1), idx + 1);
            }
        }
    }

    #[test]
    fn quantile_estimate_brackets_true_quantile() {
        forall("hist quantile brackets truth", 64, |g| {
            let n = g.len(1..=400);
            let h = Hist::new();
            let mut vals: Vec<u64> = Vec::with_capacity(n);
            for _ in 0..n {
                let shift = g.usize_in(0..=63);
                let v = g.rng().next_u64() >> shift;
                h.record(v);
                vals.push(v);
            }
            vals.sort_unstable();
            let snap = h.snapshot();
            for q in [0.5, 0.95, 0.99] {
                let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
                let truth = vals[rank - 1];
                let (lo, hi) = snap.quantile(q);
                if !(lo <= truth && truth <= hi) {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn concurrent_record_loses_no_counts() {
        let h = Hist::new();
        let threads = 4;
        let per = 10_000u64;
        std::thread::scope(|s| {
            for t in 0..threads {
                let h = &h;
                s.spawn(move || {
                    for i in 0..per {
                        // mixed scales so several buckets contend
                        h.record((t * per + i) << (i % 16));
                    }
                });
            }
        });
        let snap = h.snapshot();
        assert_eq!(snap.count, threads * per);
        let bucket_total: u64 = snap.buckets.iter().sum();
        assert_eq!(bucket_total, threads * per, "no increments lost");
    }

    #[test]
    fn snapshot_sum_and_cumulative_agree() {
        let h = Hist::new();
        for v in [0u64, 1, 7, 8, 100, 1_000_000] {
            h.record(v);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, 6);
        assert_eq!(snap.sum, 1_001_116);
        // le = 2^3 - 1 = 7 covers {0, 1, 7}
        assert_eq!(snap.cumulative_at_octave(3), 3);
        // le = 2^7 - 1 = 127 covers {0, 1, 7, 8, 100}
        assert_eq!(snap.cumulative_at_octave(7), 5);
        assert_eq!(snap.cumulative_at_octave(63), 6);
        // empty histogram quantile is the (0,0) sentinel
        assert_eq!(Hist::new().snapshot().quantile(0.5), (0, 0));
    }
}
