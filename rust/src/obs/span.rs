//! Cheap timing spans for the request/step lifecycle.
//!
//! A [`Span`] brackets one stage (queue wait, batch assembly, one ODE
//! step, one layer sweep, ...) and records the elapsed nanoseconds into a
//! [`Hist`] on [`Span::end`]. Two off switches, with different costs:
//!
//! * **runtime** — [`set_timing_enabled`]`(false)` makes [`Span::begin`]
//!   skip the clock read; the residual cost is one `Relaxed` atomic load
//!   and a branch per span (measured by `bench_engine`'s obs-overhead
//!   section, gated at ≤ 3% per ODE step with timing *on*);
//! * **compile time** — the `no-obs` cargo feature compiles [`Span`] to a
//!   zero-sized no-op and [`record_since`] to an empty body, for exactly
//!   0% overhead on builds that must not carry instrumentation.
//!
//! Timing never changes sampling results: spans only read the clock and
//! bump atomics, so outputs are bit-identical with instrumentation on or
//! off (pinned by `flow::sampler`'s on/off test).

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use crate::obs::hist::Hist;

/// Process-wide runtime kill-switch for span timing. On by default.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Turn span timing on or off at runtime (counters and direct histogram
/// records are unaffected — only clock reads stop).
pub fn set_timing_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether spans are currently reading the clock. Always `false` under
/// the `no-obs` feature.
pub fn timing_enabled() -> bool {
    if cfg!(feature = "no-obs") {
        return false;
    }
    ENABLED.load(Ordering::Relaxed)
}

/// An in-flight timing span. Obtain with [`Span::begin`], close with
/// [`Span::end`] into the target histogram. Alloc-free (enrolled via the
/// `Span::*` `no_alloc` root) and infallible.
#[cfg(not(feature = "no-obs"))]
#[must_use = "a span only records when end() is called"]
pub struct Span {
    t0: Option<Instant>,
}

#[cfg(not(feature = "no-obs"))]
impl Span {
    /// Start a span; reads the clock only while timing is enabled.
    #[inline]
    pub fn begin() -> Self {
        Span {
            t0: if timing_enabled() { Some(Instant::now()) } else { None },
        }
    }

    /// Close the span, recording elapsed nanoseconds into `h`.
    #[inline]
    pub fn end(self, h: &Hist) {
        if let Some(t0) = self.t0 {
            record_since(h, t0);
        }
    }
}

/// No-op twin compiled under `no-obs`: zero-sized, fully inert.
#[cfg(feature = "no-obs")]
#[must_use = "a span only records when end() is called"]
pub struct Span;

#[cfg(feature = "no-obs")]
impl Span {
    /// Start a span (no-op under `no-obs`).
    #[inline]
    pub fn begin() -> Self {
        Span
    }

    /// Close the span (no-op under `no-obs`).
    #[inline]
    pub fn end(self, _h: &Hist) {}
}

/// Record the nanoseconds elapsed since `t0` into `h`. The free-function
/// form of [`Span::end`] for call sites that already hold an `Instant`.
#[cfg(not(feature = "no-obs"))]
#[fmq_macros::no_alloc]
pub fn record_since(h: &Hist, t0: Instant) {
    let ns = t0.elapsed().as_nanos();
    h.record(if ns > u64::MAX as u128 { u64::MAX } else { ns as u64 });
}

/// No-op twin compiled under `no-obs`.
#[cfg(feature = "no-obs")]
#[fmq_macros::no_alloc]
pub fn record_since(_h: &Hist, _t0: Instant) {}

/// Serializes unit tests that toggle the process-global timing switch
/// (they run on parallel threads in one test binary).
#[cfg(test)]
pub(crate) static TEST_TIMING_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_records_only_while_enabled() {
        let _g = TEST_TIMING_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let h = Hist::new();
        set_timing_enabled(true);
        let s = Span::begin();
        s.end(&h);
        let on_count = h.snapshot().count;

        set_timing_enabled(false);
        let s = Span::begin();
        s.end(&h);
        let off_count = h.snapshot().count;
        set_timing_enabled(true);

        if cfg!(feature = "no-obs") {
            assert_eq!(on_count, 0);
            assert_eq!(off_count, 0);
        } else {
            assert_eq!(on_count, 1);
            assert_eq!(off_count, 1, "disabling must not retro-drop");
            // the disabled span added nothing
            assert_eq!(off_count - on_count, 0);
        }
    }

    #[test]
    fn record_since_is_nonnegative_and_counted() {
        let h = Hist::new();
        let t0 = Instant::now();
        record_since(&h, t0);
        if cfg!(feature = "no-obs") {
            assert_eq!(h.snapshot().count, 0);
        } else {
            assert_eq!(h.snapshot().count, 1);
        }
    }
}
