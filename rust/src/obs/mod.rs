//! Observability: a static, fixed-capacity, alloc-free metrics registry
//! with timing spans and Prometheus-style exposition.
//!
//! Three pieces (full catalogue + conventions: `docs/OBSERVABILITY.md`):
//!
//! * **Registry** — [`Counter`] / [`Gauge`] / [`Hist`] primitives, all
//!   `const`-constructible and lock-free. Every metric is a named struct
//!   field registered at startup: [`Metrics`] is the per-server registry
//!   (one `Arc` per [`crate::coordinator::server::Server`], replacing the
//!   old ad-hoc `ServerStats`), and [`ENGINE`] is the process-global
//!   engine registry reached directly from kernel code (`obs::ENGINE.x`)
//!   with zero setup. Record paths allocate nothing and are enrolled in
//!   `cargo xtask lint`'s `no_alloc` rule via wildcard roots
//!   (`Hist::*`, `Counter::*`, `Gauge::*`, `Span::*`) in `lint.toml`.
//! * **Spans** — [`Span`] / [`record_since`] bracket lifecycle stages
//!   (queue wait, batch assembly, ODE steps, layer sweeps, reply
//!   serialization) into histograms; runtime-disablable via
//!   [`set_timing_enabled`] and compiled out entirely by the `no-obs`
//!   cargo feature. Timing never changes sampling outputs.
//! * **Exposition** — [`render_prometheus`] / [`render_json`] snapshot
//!   both registries into Prometheus text-format (with p50/p95/p99
//!   bracketed quantile estimates) or integer-exact JSON; served by the
//!   server's `metrics` protocol op and the `--metrics-dump` flag.

pub mod expo;
pub mod hist;
pub mod span;

pub use expo::{render_json, render_prometheus};
pub use hist::{Hist, HistSnapshot, BUCKETS};
pub use span::{record_since, set_timing_enabled, timing_enabled, Span};

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

/// A monotonically increasing event count. Lock-free, alloc-free.
pub struct Counter(AtomicU64);

impl Counter {
    /// A zeroed counter (`const` — usable in `static` registries).
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    /// Add one.
    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Add `n`.
    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Self {
        Self::new()
    }
}

/// A signed instantaneous value (queue depth, resident bytes). Signed so
/// concurrent `+delta`/`-delta` updates from different threads can
/// transiently net below a reader's expectation without wrapping to
/// 2^64-ish garbage — a reader can *see* (and a test can assert against)
/// any accounting bug as a negative value instead.
pub struct Gauge(AtomicI64);

impl Gauge {
    /// A zeroed gauge (`const` — usable in `static` registries).
    pub const fn new() -> Self {
        Gauge(AtomicI64::new(0))
    }

    /// Apply a signed delta in one atomic update.
    #[inline]
    pub fn add(&self, delta: i64) {
        self.0.fetch_add(delta, Ordering::Relaxed);
    }

    /// Overwrite the value.
    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

/// Wire error-class labels, index-aligned with
/// [`Metrics::errors_by_class`]. The spellings are the `code` strings of
/// [`crate::coordinator::errors::ErrClass`] (asserted by a test there);
/// exposition renders one labelled sample per entry.
pub const ERROR_CLASSES: [&str; 8] = [
    "bad_request",
    "unknown_model",
    "worker_panic",
    "deadline_exceeded",
    "overloaded",
    "shutting_down",
    "corrupt_artifact",
    "internal",
];

/// Per-server metrics registry: one instance per
/// [`crate::coordinator::server::Server`], shared via `Arc` with every
/// worker and connection thread. Fixed capacity — every metric is a
/// struct field, registered here at startup; recording is field access
/// plus an atomic op, never a lookup.
pub struct Metrics {
    /// Requests admitted (`generate` + `encode`).
    pub requests: Counter,
    /// Batches executed by variant workers.
    pub batches: Counter,
    /// Samples produced by `generate` requests.
    pub samples: Counter,
    /// `encode` requests served.
    pub encodes: Counter,
    /// Requests that returned an error reply.
    pub errors: Counter,
    /// Error replies by class, index-aligned with [`ERROR_CLASSES`].
    /// Sums to `errors` (both are bumped together in `handle_conn`).
    pub errors_by_class: [Counter; 8],
    /// Worker threads respawned by the supervisor after a panic.
    pub worker_respawns: Counter,
    /// Requests shed by admission control (queue full → `overloaded`).
    pub shed: Counter,
    /// Connections that died mid-reply (client gone before the write).
    pub conn_drops: Counter,
    /// Rows admitted but not yet completed, across all variant queues.
    pub queue_depth: Gauge,
    /// Packed model bytes resident across serving variants.
    pub resident_bytes: Gauge,
    /// High-water workspace-arena bytes across variant workers.
    pub workspace_bytes: Gauge,
    /// End-to-end request latency (admission to reply built), ns.
    pub request_latency_ns: Hist,
    /// Admission → first time a request's rows are assembled, ns.
    pub queue_wait_ns: Hist,
    /// Time to assemble one batch's inputs, ns.
    pub batch_assemble_ns: Hist,
    /// Time to run one batch through the sampler, ns.
    pub batch_run_ns: Hist,
    /// Rows per executed batch.
    pub batch_rows: Hist,
    /// Time to serialize + write one reply line, ns.
    pub reply_serialize_ns: Hist,
}

impl Metrics {
    /// A zeroed registry (`const`).
    pub const fn new() -> Self {
        Metrics {
            requests: Counter::new(),
            batches: Counter::new(),
            samples: Counter::new(),
            encodes: Counter::new(),
            errors: Counter::new(),
            errors_by_class: [
                Counter::new(),
                Counter::new(),
                Counter::new(),
                Counter::new(),
                Counter::new(),
                Counter::new(),
                Counter::new(),
                Counter::new(),
            ],
            worker_respawns: Counter::new(),
            shed: Counter::new(),
            conn_drops: Counter::new(),
            queue_depth: Gauge::new(),
            resident_bytes: Gauge::new(),
            workspace_bytes: Gauge::new(),
            request_latency_ns: Hist::new(),
            queue_wait_ns: Hist::new(),
            batch_assemble_ns: Hist::new(),
            batch_run_ns: Hist::new(),
            batch_rows: Hist::new(),
            reply_serialize_ns: Hist::new(),
        }
    }

    /// The per-class error counter for a wire `code` string. Cold path
    /// (only runs while building an error reply); the linear scan over 8
    /// static labels keeps the registry const-constructible. Unknown
    /// codes fall back to the `internal` slot rather than dropping the
    /// count.
    pub fn error_class(&self, code: &str) -> &Counter {
        let idx = ERROR_CLASSES
            .iter()
            .position(|&c| c == code)
            .unwrap_or(ERROR_CLASSES.len() - 1);
        self.errors_by_class.get(idx).unwrap_or(&self.errors)
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Process-global engine registry, reached as `obs::ENGINE.field` from
/// kernel-depth code (sampler step loop, LUT sweeps, autotuner) where no
/// per-server handle can be threaded without polluting `Engine` trait
/// signatures. Global is correct here: these measure the process's
/// compute, aggregated across every engine instance.
pub static ENGINE: EngineMetrics = EngineMetrics::new();

/// The engine-side registry behind [`ENGINE`].
pub struct EngineMetrics {
    /// One Euler ODE step over a batch (`EngineStep::run` body), ns.
    pub ode_step_ns: Hist,
    /// One layer GEMM inside the fused forward, ns.
    pub layer_sweep_ns: Hist,
    /// One v2 blocked-kernel stripe invocation, ns.
    pub v2_kernel_ns: Hist,
    /// Autotune plan measurements (cache misses) performed.
    pub tune_plans_total: Counter,
    /// Shard jobs dispatched by the pool (rows + columns axes).
    pub shard_jobs_total: Counter,
}

impl EngineMetrics {
    /// A zeroed registry (`const` — this is a `static`).
    pub const fn new() -> Self {
        EngineMetrics {
            ode_step_ns: Hist::new(),
            layer_sweep_ns: Hist::new(),
            v2_kernel_ns: Hist::new(),
            tune_plans_total: Counter::new(),
            shard_jobs_total: Counter::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);

        let g = Gauge::new();
        g.add(10);
        g.add(-25);
        assert_eq!(g.get(), -15, "gauges must represent negative states");
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn engine_registry_is_recordable_from_anywhere() {
        let before = ENGINE.shard_jobs_total.get();
        ENGINE.shard_jobs_total.add(3);
        assert!(ENGINE.shard_jobs_total.get() >= before + 3);
    }
}
