//! # fmq — Low-Bit, High-Fidelity: OT Quantization for Flow Matching
//!
//! Full-system reproduction of *"Low-Bit, High-Fidelity: Optimal Transport
//! Quantization for Flow Matching"* (Varam et al., 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: quantizers (the paper's
//!   contribution, [`quant`]), theory calculator ([`theory`]), synthetic
//!   datasets ([`data`]), metrics ([`metrics`]), training/sampling drivers
//!   ([`flow`]), experiment sweeps and a serving layer ([`coordinator`]).
//! * **Native inference ([`engine`])** — the low-bit serving hot path:
//!   two generations of LUT-GEMM kernels that execute the velocity
//!   network **directly from packed codebook indices** (no dense f32
//!   dequantization) — v1 (`lut`, per-activation tables, bit-exact vs
//!   the reference) and v2 (`lut2`, cache-blocked with fused multi-code
//!   tables and measured tile autotuning) — plus a std-thread pool with
//!   batch-sharding and intra-layer column-sharding axes, and per-worker
//!   workspace arenas (`engine::workspace`) that make the steady-state
//!   sampling path allocation-free.
//! * **Layer 2/1 (build-time python, `pjrt` feature)** — the flow-matching
//!   velocity network and the Pallas `qmm`/`assign` kernels, AOT-lowered
//!   to HLO text and executed through the PJRT C API by [`runtime`].
//!   Python never runs on the request path; without the feature a stub
//!   keeps the API and everything falls back to the native engines.
//!
//! ## Execution-path layering
//!
//! ```text
//!  request ──> coordinator::server ──> coordinator::batcher ─┐
//!                                                            │ one batch
//!                                                            v
//!                     flow::sampler (StepBackend / EngineStep)
//!                 │             │             │               │
//!             EngineKind::  EngineKind::  EngineKind::   EngineKind::
//!               CpuRef         Lut           Lut2          Runtime
//!                 │             │             │               │
//!          flow::cpu_ref  engine::lut   engine::blocked  runtime::artifacts
//!          (dequant +     (v1 LUT-GEMM  (v2 blocked,     (compiled HLO via
//!           dense f32      over packed   fused tables,    PJRT, `pjrt`
//!           GEMM)          codes)        engine::tune)    feature)
//!                 \             │             │
//!                  \       engine::forward (one op sequence)
//!                   \           │             │
//!                    `────── engine::pool (rows ∥ columns) ──────'
//! ```
//!
//! The prose walkthrough of this diagram — train → quantize → pack →
//! engine → batcher/server, including the `Engine` trait contract and
//! where the v2 dispatch plugs in — lives in `docs/ARCHITECTURE.md`;
//! how to measure every stage is in `docs/BENCHMARKS.md`.
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use fmq::engine::{Engine, LutEngine};
//! use fmq::model::spec::ModelSpec;
//! use fmq::quant::{QuantMethod, quantize_model};
//! use fmq::util::rng::Pcg64;
//!
//! let spec = ModelSpec::default_spec();
//! let mut rng = Pcg64::seed(7);
//! let theta = spec.init_theta(&mut rng);
//! let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 4);
//! println!("W2 err = {}", qm.total_w2_error());
//! // serve straight from the packed codes — no dense dequantization
//! let eng = LutEngine::new(&qm).unwrap();
//! let x = vec![0.0f32; spec.d];
//! let v = eng.velocity(&x, &[0.5]).unwrap();
//! assert_eq!(v.len(), spec.d);
//! ```

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod faults;
pub mod flow;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod stats;
pub mod sweep;
pub mod tensor;
pub mod theory;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
