//! # fmq — Low-Bit, High-Fidelity: OT Quantization for Flow Matching
//!
//! Full-system reproduction of *"Low-Bit, High-Fidelity: Optimal Transport
//! Quantization for Flow Matching"* (Varam et al., 2025) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: quantizers (the paper's
//!   contribution, [`quant`]), theory calculator ([`theory`]), synthetic
//!   datasets ([`data`]), metrics ([`metrics`]), training/sampling drivers
//!   ([`flow`]), experiment sweeps and a serving layer ([`coordinator`]).
//! * **Native inference ([`engine`])** — the low-bit serving hot path:
//!   LUT-GEMM kernels that execute the velocity network **directly from
//!   packed codebook indices** (no dense f32 dequantization), plus a
//!   std-thread pool that shards sample batches across cores.
//! * **Layer 2/1 (build-time python, `pjrt` feature)** — the flow-matching
//!   velocity network and the Pallas `qmm`/`assign` kernels, AOT-lowered
//!   to HLO text and executed through the PJRT C API by [`runtime`].
//!   Python never runs on the request path; without the feature a stub
//!   keeps the API and everything falls back to the native engines.
//!
//! ## Execution-path layering
//!
//! ```text
//!  request ──> coordinator::server ──> coordinator::batcher ─┐
//!                                                            │ one batch
//!                                                            v
//!                         flow::sampler (StepBackend / EngineStep)
//!                           │                │               │
//!                 EngineKind::CpuRef   EngineKind::Lut   EngineKind::Runtime
//!                           │                │               │
//!                  flow::cpu_ref      engine::forward    runtime::artifacts
//!                  (dequant + dense   (LUT-GEMM over     (compiled HLO via
//!                   f32 GEMM)          packed codes,      PJRT, `pjrt`
//!                                      engine::pool)      feature)
//! ```
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use fmq::engine::{Engine, LutEngine};
//! use fmq::model::spec::ModelSpec;
//! use fmq::quant::{QuantMethod, quantize_model};
//! use fmq::util::rng::Pcg64;
//!
//! let spec = ModelSpec::default_spec();
//! let mut rng = Pcg64::seed(7);
//! let theta = spec.init_theta(&mut rng);
//! let qm = quantize_model(&spec, &theta, QuantMethod::Ot, 4);
//! println!("W2 err = {}", qm.total_w2_error());
//! // serve straight from the packed codes — no dense dequantization
//! let eng = LutEngine::new(&qm).unwrap();
//! let x = vec![0.0f32; spec.d];
//! let v = eng.velocity(&x, &[0.5]).unwrap();
//! assert_eq!(v.len(), spec.d);
//! ```

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod flow;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod stats;
pub mod tensor;
pub mod theory;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
