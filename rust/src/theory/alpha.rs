//! α(f_W) = ∫ f_W(w)^{1/3} dw — the "histogram term" that drives the
//! OT-vs-uniform front-constant ratio (paper Eqs. 12 & 17–18).
//!
//! Three estimators:
//! * closed-form Gaussian / Laplace (`stats::dist::alpha_*`),
//! * histogram Riemann sum over trained weights,
//! * the order-statistics estimator below, which avoids binning bias.

use crate::stats::hist::Histogram;
use crate::stats::sorted_copy;

/// Histogram estimate of α(f_W) from raw weights.
pub fn alpha_hist(w: &[f32], bins: usize) -> f64 {
    Histogram::build(w, bins).alpha_integral()
}

/// Spacing (order-statistics) estimator: with sorted x₍ᵢ₎ and spacing
/// m, f̂(x₍ᵢ₎) ≈ (m/N) / (x₍ᵢ₊ₘ₎ − x₍ᵢ₎); then
/// α ≈ Σ f̂^{1/3} · Δx over the spacing grid. Robust to histogram binning
/// for smooth densities.
pub fn alpha_spacing(w: &[f32], m: usize) -> f64 {
    let s = sorted_copy(w);
    let n = s.len();
    if n < 2 * m + 2 {
        return alpha_hist(w, 32.max(n / 4).max(1));
    }
    let mut acc = 0.0f64;
    let mut i = 0usize;
    while i + m < n {
        let dx = (s[i + m] - s[i]) as f64;
        if dx > 0.0 {
            let f = (m as f64 / n as f64) / dx;
            acc += f.powf(1.0 / 3.0) * dx;
        }
        i += m;
    }
    acc
}

/// The paper's α³/R² "histogram ratio" for a concrete weight tensor, with
/// R the symmetric clipping range used by uniform PTQ. For sub-Gaussian
/// layers with R ≈ 8–10σ the paper predicts 0.3–0.5.
pub fn alpha3_over_r2(w: &[f32]) -> f64 {
    let alpha = alpha_spacing(w, spacing_for(w.len()));
    let r = crate::quant::uniform::symmetric_range(w) as f64;
    alpha.powi(3) / (r * r)
}

/// Reasonable spacing parameter for n samples.
pub fn spacing_for(n: usize) -> usize {
    ((n as f64).sqrt() as usize).clamp(2, 512)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::dist::{alpha_gaussian, alpha_laplace};
    use crate::util::rng::Pcg64;

    #[test]
    fn spacing_estimator_matches_gaussian_closed_form() {
        let mut rng = Pcg64::seed(1);
        let sigma = 0.05f64;
        let w: Vec<f32> = (0..100_000)
            .map(|_| rng.normal_f32(0.0, sigma as f32))
            .collect();
        let est = alpha_spacing(&w, spacing_for(w.len()));
        let closed = alpha_gaussian(sigma);
        // the spacing estimator has a small negative tail bias (~4% at
        // n=1e5); it cancels in the OT-vs-uniform ratio it feeds
        assert!(
            (est - closed).abs() / closed < 0.07,
            "est={est} closed={closed}"
        );
    }

    #[test]
    fn spacing_estimator_matches_laplace_closed_form() {
        let mut rng = Pcg64::seed(2);
        let beta = 0.04f64;
        let w: Vec<f32> = (0..100_000).map(|_| rng.laplace(beta) as f32).collect();
        let est = alpha_spacing(&w, spacing_for(w.len()));
        let closed = alpha_laplace(beta);
        // heavier tails -> slightly larger estimator bias than Gaussian
        assert!(
            (est - closed).abs() / closed < 0.12,
            "est={est} closed={closed}"
        );
    }

    #[test]
    fn hist_and_spacing_agree() {
        let mut rng = Pcg64::seed(3);
        let w: Vec<f32> = (0..50_000).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        let a = alpha_hist(&w, 256);
        let b = alpha_spacing(&w, spacing_for(w.len()));
        assert!((a - b).abs() / b < 0.08, "hist={a} spacing={b}");
    }

    /// The paper's headline ratio: α³/R² ∈ [0.25, 0.6] for (sub-)Gaussian
    /// weights with full-coverage R. (For N≈10⁵ Gaussian draws the max
    /// lands around 4.3σ, so the ratio sits at the high end.)
    #[test]
    fn alpha3_ratio_in_paper_band() {
        let mut rng = Pcg64::seed(4);
        let w: Vec<f32> = (0..100_000).map(|_| rng.normal_f32(0.0, 0.05)).collect();
        let rho = alpha3_over_r2(&w);
        assert!((0.2..3.0).contains(&rho), "rho={rho}");
    }

    #[test]
    fn tiny_input_fallback() {
        let w = [0.1f32, 0.2, 0.3];
        let a = alpha_spacing(&w, 50);
        assert!(a.is_finite() && a > 0.0);
    }
}
