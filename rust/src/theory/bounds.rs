//! The paper's FID upper bounds, executable.
//!
//! Theorem 3 (uniform):  FID(T) ≤ C_U · 2^{-2b},
//!   C_U = L_φ² [ (L_θ^∞ / L_x)(e^{L_x T} − 1) R ]²
//! Theorem 6 (OT):       FID(T) ≤ C_E · 2^{-2b},
//!   C_E = L_φ² [ (L_θ² √p / L_x)(e^{L_x T} − 1) ]² · α(f_W)³ / 12
//! ρ(b) = C_E / C_U (Eq. 17) — the provable-advantage ratio, and the two
//! bit-budget corollaries 13.1/13.2.

/// Everything the bounds need, bundled.
#[derive(Clone, Copy, Debug)]
pub struct BoundInputs {
    /// state-Lipschitz constant L_x (Assumption 1-A)
    pub l_x: f64,
    /// worst-case parameter sensitivity L_θ^∞ (Assumption 1-B)
    pub l_theta_inf: f64,
    /// rms parameter sensitivity L_θ² (Assumption 1-C)
    pub l_theta_2: f64,
    /// feature-extractor Lipschitz constant L_φ (Assumption 1-D)
    pub l_phi: f64,
    /// integration horizon T
    pub t: f64,
    /// uniform clipping range R
    pub r: f64,
    /// parameter count p (noise sources in Lemma 4)
    pub p: f64,
    /// α(f_W) of the weight density
    pub alpha: f64,
}

/// The shared ODE amplification factor (e^{L_x T} − 1)/L_x, with the
/// L_x → 0 limit handled (paper Lemma 1 boundary case).
pub fn amplification(l_x: f64, t: f64) -> f64 {
    if l_x.abs() < 1e-12 {
        t
    } else {
        ((l_x * t).exp() - 1.0) / l_x
    }
}

/// Lemma 1 instantiated with *measured* constants: if the velocity gap
/// between the quantized and reference fields is ≤ `dv_max` along the
/// quantized trajectory's visited states, and the reference field is
/// `l_x`-Lipschitz in x between the two trajectories, then the endpoint
/// deviation obeys ‖x_q(t) − x(t)‖ ≤ dv_max · (e^{l_x t} − 1)/l_x.
///
/// The discrete (fixed-step Euler) error recursion
/// `e_{s+1} ≤ (1 + dt·l_x)·e_s + dt·dv_max` telescopes to
/// `dv_max·((1+dt·l_x)^N − 1)/l_x`, which this continuous form dominates
/// ((1+z) ≤ e^z) — so the sweep's per-cell conformance check
/// `measured deviation ≤ trajectory_bound(L̂, t, d̂v)` is a theorem
/// whenever L̂ and d̂v really dominate the per-step constants (the sweep
/// measures both along the actual trajectory pair).
pub fn trajectory_bound(l_x: f64, t: f64, dv_max: f64) -> f64 {
    amplification(l_x, t) * dv_max
}

impl BoundInputs {
    /// Front constant C_U of Theorem 3.
    pub fn c_uniform(&self) -> f64 {
        let amp = amplification(self.l_x, self.t);
        let inner = self.l_theta_inf * amp * self.r;
        self.l_phi * self.l_phi * inner * inner
    }

    /// Front constant C_E of Theorem 6.
    pub fn c_ot(&self) -> f64 {
        let amp = amplification(self.l_x, self.t);
        let inner = self.l_theta_2 * self.p.sqrt() * amp;
        self.l_phi * self.l_phi * inner * inner * self.alpha.powi(3) / 12.0
    }

    /// ρ = C_E / C_U (Eq. 17).
    pub fn rho(&self) -> f64 {
        self.c_ot() / self.c_uniform()
    }

    /// Theorem 3: FID bound at bit-width b.
    pub fn fid_bound_uniform(&self, bits: u8) -> f64 {
        self.c_uniform() * 2.0f64.powi(-2 * bits as i32)
    }

    /// Theorem 6: FID bound at bit-width b.
    pub fn fid_bound_ot(&self, bits: u8) -> f64 {
        self.c_ot() * 2.0f64.powi(-2 * bits as i32)
    }

    /// Trajectory error bound ε_U(t, b) (Lemma 1).
    pub fn eps_uniform(&self, t: f64, bits: u8) -> f64 {
        let delta_u = self.r / 2.0f64.powi(bits as i32 - 1);
        self.l_theta_inf * delta_u * amplification(self.l_x, t)
    }

    /// Mean trajectory error bound ε_E(t, b) (Lemma 5) with
    /// D_E = α³/12 · 2^{-2b}.
    pub fn eps_ot(&self, t: f64, bits: u8) -> f64 {
        let d_e = self.alpha.powi(3) / 12.0 * 2.0f64.powi(-2 * bits as i32);
        self.l_theta_2 * (self.p * d_e).sqrt() * amplification(self.l_x, t)
    }

    /// Corollary 13.1: minimum bit-width guaranteeing FID gap ≤ Δ_max.
    pub fn bit_budget(&self, delta_max: f64, ot: bool) -> u8 {
        let c = if ot { self.c_ot() } else { self.c_uniform() };
        // 2^{-2b} <= Δ/C  =>  b >= 0.5 log2(C/Δ)
        let b = 0.5 * (c / delta_max).log2();
        b.ceil().max(1.0) as u8
    }

    /// Corollary 13.2: FID bound achievable at a given bit-width (inverse
    /// phrasing of 13.1, useful for the budget table).
    pub fn achievable_fid(&self, bits: u8, ot: bool) -> f64 {
        if ot {
            self.fid_bound_ot(bits)
        } else {
            self.fid_bound_uniform(bits)
        }
    }

    /// Paper defaults for the analytic comparison table: Gaussian weights,
    /// kσ clipping, L_θ²√p ≈ L_θ^∞ R (the paper's "in practice" premise).
    pub fn paper_defaults(sigma: f64, k_sigma: f64) -> Self {
        let r = k_sigma * sigma;
        BoundInputs {
            l_x: 1.0,
            l_theta_inf: 1.0,
            l_theta_2: r / (1.0f64 * 1e6).sqrt(), // makes L_θ²√p = L_θ^∞ R at p=1e6
            l_phi: 1.0,
            t: 1.0,
            r,
            p: 1e6,
            alpha: crate::stats::dist::alpha_gaussian(sigma),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs() -> BoundInputs {
        BoundInputs::paper_defaults(0.05, 10.0)
    }

    #[test]
    fn amplification_limit_lx_zero() {
        assert!((amplification(0.0, 2.0) - 2.0).abs() < 1e-12);
        // continuity near zero
        assert!((amplification(1e-9, 2.0) - 2.0).abs() < 1e-6);
        // known value
        assert!((amplification(1.0, 1.0) - (1.0f64.exp() - 1.0)).abs() < 1e-12);
    }

    /// The measured-constant Grönwall bound: L→0 limit is t·dv, and it
    /// dominates the discrete fixed-step recursion it certifies.
    #[test]
    fn trajectory_bound_dominates_discrete_recursion() {
        assert!((trajectory_bound(0.0, 1.0, 0.25) - 0.25).abs() < 1e-12);
        for &(l, steps) in &[(0.5f64, 4usize), (2.0, 16), (5.0, 3)] {
            let dv = 0.1;
            let dt = 1.0 / steps as f64;
            let mut e = 0.0f64;
            for _ in 0..steps {
                e = (1.0 + dt * l) * e + dt * dv;
            }
            let bound = trajectory_bound(l, 1.0, dv);
            assert!(e <= bound * (1.0 + 1e-12), "l={l} steps={steps}: {e} > {bound}");
        }
        // monotone in every argument
        assert!(trajectory_bound(2.0, 1.0, 0.1) > trajectory_bound(1.0, 1.0, 0.1));
        assert!(trajectory_bound(1.0, 1.0, 0.2) > trajectory_bound(1.0, 1.0, 0.1));
        assert!(trajectory_bound(1.0, 1.0, 0.1) > trajectory_bound(1.0, 0.5, 0.1));
    }

    /// The paper's headline numbers, dimensionally untangled. Eq. 17 writes
    /// ρ = [(L_θ²√p)/(L_θ^∞ R)]² · α³/12 and then quotes ρ ≈ 0.25–0.4 from
    /// α³ ≈ 0.33 R² — but that substitution only yields 0.33 if the /12 is
    /// silently absorbed AND the premise is L_θ²√p ≈ L_θ^∞ (sans R). We
    /// implement the theorems exactly as stated: with L_θ²√p = L_θ^∞ R
    /// (paper's "in practice" premise, which our defaults enforce) the R²
    /// cancels and ρ = α³/12. The *paper-quoted* ratio α³/R² = 0.33 (k=10σ)
    /// is checked separately; both agree that OT's constant is strictly
    /// tighter. (Noted in DESIGN.md §paper-errata.)
    #[test]
    fn rho_matches_paper_gaussian_k10() {
        let b = inputs();
        // the quoted histogram ratio (paper: "k=10 => 0.33")
        let ratio = b.alpha.powi(3) / (b.r * b.r);
        assert!((ratio - 0.3267).abs() < 0.01, "ratio={ratio}");
        // rho as Eq. 17 actually evaluates under the stated premise
        let rho = b.rho();
        assert!((rho - b.alpha.powi(3) / 12.0).abs() < 1e-9, "rho={rho}");
        assert!(rho < 1.0, "OT front-constant must be tighter");
    }

    #[test]
    fn laplace_ratio_is_054() {
        // paper: Laplace α³ = 54 σ², k=10 ⇒ α³/R² = 0.54
        let sigma = 0.05f64;
        let beta = sigma / std::f64::consts::SQRT_2;
        let alpha = crate::stats::dist::alpha_laplace(beta);
        let r = 10.0 * sigma;
        let ratio = alpha.powi(3) / (r * r);
        assert!((ratio - 0.54).abs() < 0.01, "ratio={ratio}");
    }

    #[test]
    fn fid_bounds_scale_as_2_pow_minus_2b() {
        let b = inputs();
        for bits in 2..8u8 {
            let r_u = b.fid_bound_uniform(bits) / b.fid_bound_uniform(bits + 1);
            let r_o = b.fid_bound_ot(bits) / b.fid_bound_ot(bits + 1);
            assert!((r_u - 4.0).abs() < 1e-9);
            assert!((r_o - 4.0).abs() < 1e-9);
        }
    }

    #[test]
    fn ot_bound_tighter_at_every_bitwidth() {
        let b = inputs();
        for bits in 2..=8u8 {
            assert!(b.fid_bound_ot(bits) < b.fid_bound_uniform(bits));
        }
    }

    /// Corollary 13.1's "two extra bits of headroom": with ρ < 1/4? No —
    /// ρ ≈ 0.027 here (ratio/12), so OT admits ⌈log₄(1/ρ)⌉ ≈ 2–3 fewer
    /// bits at the same budget.
    #[test]
    fn bit_budget_headroom() {
        let b = inputs();
        for delta in [1e-4, 1e-3, 1e-2] {
            let bu = b.bit_budget(delta, false);
            let bo = b.bit_budget(delta, true);
            assert!(bo < bu, "delta={delta}: ot {bo} !< uniform {bu}");
            assert!(bu - bo >= 2, "expected >= 2 bits headroom, got {}", bu - bo);
            // the budget really is satisfied at the returned bit-width
            assert!(b.achievable_fid(bu, false) <= delta * 1.0001);
            assert!(b.achievable_fid(bo, true) <= delta * 1.0001);
            // ...and violated one bit below (unless already at the floor)
            if bu > 1 {
                assert!(b.achievable_fid(bu - 1, false) > delta);
            }
        }
    }

    #[test]
    fn eps_bounds_decrease_with_bits_increase_with_t() {
        let b = inputs();
        assert!(b.eps_uniform(1.0, 4) > b.eps_uniform(1.0, 6));
        assert!(b.eps_ot(1.0, 4) > b.eps_ot(1.0, 6));
        assert!(b.eps_uniform(1.0, 4) > b.eps_uniform(0.5, 4));
        assert!(b.eps_ot(1.0, 4) > b.eps_ot(0.5, 4));
        // lemma boundary case: delta=0 equivalent (infinite bits) -> ~0
        assert!(b.eps_uniform(1.0, 30) < 1e-6);
    }
}
