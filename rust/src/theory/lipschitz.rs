//! Empirical Lipschitz-constant estimation (Assumptions 1-A/1-B/1-C).
//!
//! The paper's bounds are stated in terms of L_x, L_θ^∞ and L_θ² but never
//! measured; we estimate them by randomized finite differences through any
//! velocity oracle (the CPU reference forward or the compiled HLO), which
//! lets EXPERIMENTS.md report *concrete* bound curves for the trained
//! model rather than symbolic ones.

use crate::util::rng::Pcg64;

/// A velocity oracle: v = f(x, t) for a single state.
pub trait VelocityOracle {
    fn velocity(&mut self, x: &[f32], t: f32) -> Vec<f32>;
    fn dim(&self) -> usize;
}

fn l2(xs: &[f32]) -> f64 {
    xs.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
}

/// Estimate the state-Lipschitz constant L_x:
/// max over probes of ||f(x+δ,t) − f(x,t)|| / ||δ||.
pub fn estimate_l_x(
    oracle: &mut dyn VelocityOracle,
    rng: &mut Pcg64,
    probes: usize,
    eps: f32,
) -> f64 {
    let d = oracle.dim();
    let mut best = 0.0f64;
    for _ in 0..probes {
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = rng.uniform() as f32;
        let dir: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let dn = l2(&dir);
        let xp: Vec<f32> = x
            .iter()
            .zip(dir.iter())
            .map(|(&a, &b)| a + eps * b / dn as f32)
            .collect();
        let v0 = oracle.velocity(&x, t);
        let v1 = oracle.velocity(&xp, t);
        let dv: Vec<f32> = v0.iter().zip(v1.iter()).map(|(&a, &b)| b - a).collect();
        best = best.max(l2(&dv) / eps as f64);
    }
    best
}

/// A parameterized velocity oracle: can evaluate under perturbed params.
pub trait ParamOracle {
    fn velocity_with(&mut self, delta_theta: &[f32], x: &[f32], t: f32) -> Vec<f32>;
    fn dim(&self) -> usize;
    fn p(&self) -> usize;
}

/// Estimate L_θ^∞ (worst-case sensitivity, Assumption 1-B):
/// max ||f_{θ+Δ} − f_θ|| / ||Δ||_∞ over sign-pattern perturbations
/// (the extremal directions for the sup-norm ball).
pub fn estimate_l_theta_inf(
    oracle: &mut dyn ParamOracle,
    rng: &mut Pcg64,
    probes: usize,
    eps: f32,
) -> f64 {
    let d = oracle.dim();
    let p = oracle.p();
    let zero = vec![0f32; p];
    let mut best = 0.0f64;
    for _ in 0..probes {
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = rng.uniform() as f32;
        let delta: Vec<f32> = (0..p)
            .map(|_| if rng.next_u64() & 1 == 1 { eps } else { -eps })
            .collect();
        let v0 = oracle.velocity_with(&zero, &x, t);
        let v1 = oracle.velocity_with(&delta, &x, t);
        let dv: Vec<f32> = v0.iter().zip(v1.iter()).map(|(&a, &b)| b - a).collect();
        best = best.max(l2(&dv) / eps as f64); // ||Δ||_∞ = eps
    }
    best
}

/// Estimate L_θ² (rms sensitivity, Assumption 1-C):
/// max ||f_{θ+Δ} − f_θ|| / ||Δ||₂ over Gaussian perturbation directions.
pub fn estimate_l_theta_2(
    oracle: &mut dyn ParamOracle,
    rng: &mut Pcg64,
    probes: usize,
    eps: f32,
) -> f64 {
    let d = oracle.dim();
    let p = oracle.p();
    let zero = vec![0f32; p];
    let mut best = 0.0f64;
    for _ in 0..probes {
        let x: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = rng.uniform() as f32;
        let mut delta: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let n = l2(&delta) as f32;
        for v in delta.iter_mut() {
            *v *= eps / n;
        }
        let v0 = oracle.velocity_with(&zero, &x, t);
        let v1 = oracle.velocity_with(&delta, &x, t);
        let dv: Vec<f32> = v0.iter().zip(v1.iter()).map(|(&a, &b)| b - a).collect();
        best = best.max(l2(&dv) / eps as f64);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Linear oracle f(x,t) = A x with known operator norm.
    struct LinOracle {
        a: Vec<f32>, // [d, d]
        d: usize,
    }

    impl VelocityOracle for LinOracle {
        fn velocity(&mut self, x: &[f32], _t: f32) -> Vec<f32> {
            let d = self.d;
            let mut out = vec![0f32; d];
            for i in 0..d {
                for j in 0..d {
                    out[i] += self.a[i * d + j] * x[j];
                }
            }
            out
        }
        fn dim(&self) -> usize {
            self.d
        }
    }

    #[test]
    fn l_x_of_scaled_identity() {
        // f(x) = 3x: L_x must be ~3 exactly in every direction
        let d = 16;
        let mut a = vec![0f32; d * d];
        for i in 0..d {
            a[i * d + i] = 3.0;
        }
        let mut o = LinOracle { a, d };
        let mut rng = Pcg64::seed(1);
        let l = estimate_l_x(&mut o, &mut rng, 32, 1e-2);
        assert!((l - 3.0).abs() < 1e-3, "l={l}");
    }

    #[test]
    fn l_x_lower_bounds_operator_norm() {
        // diag(1, 5): probes should find >= ~3 (can't exceed 5)
        let d = 2;
        let a = vec![1.0, 0.0, 0.0, 5.0];
        let mut o = LinOracle { a, d };
        let mut rng = Pcg64::seed(2);
        let l = estimate_l_x(&mut o, &mut rng, 200, 1e-2);
        assert!(l > 3.0 && l <= 5.0 + 1e-3, "l={l}");
    }

    /// Oracle whose param dependence is f = x + Δθ (p == d).
    struct ShiftOracle {
        d: usize,
    }

    impl ParamOracle for ShiftOracle {
        fn velocity_with(&mut self, dt: &[f32], x: &[f32], _t: f32) -> Vec<f32> {
            x.iter().zip(dt.iter()).map(|(&a, &b)| a + b).collect()
        }
        fn dim(&self) -> usize {
            self.d
        }
        fn p(&self) -> usize {
            self.d
        }
    }

    #[test]
    fn l_theta_norms_of_shift_oracle() {
        // ||f_{θ+Δ} − f_θ|| = ||Δ||₂. With sign patterns ||Δ||₂ = √p·ε so
        // L_θ^∞ = √p; with normalized gaussian Δ, L_θ² = 1.
        let mut o = ShiftOracle { d: 64 };
        let mut rng = Pcg64::seed(3);
        let linf = estimate_l_theta_inf(&mut o, &mut rng, 16, 1e-3);
        assert!((linf - 8.0).abs() < 1e-2, "linf={linf}");
        let l2n = estimate_l_theta_2(&mut o, &mut rng, 16, 1e-3);
        assert!((l2n - 1.0).abs() < 1e-3, "l2={l2n}");
    }
}
