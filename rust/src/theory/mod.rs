//! The paper's theory, executable: α(f_W), the FID upper bounds of
//! Theorems 3/6, the ρ(b) front-constant ratio, bit-budget corollaries
//! 13.1/13.2, and empirical Lipschitz-constant estimation.

pub mod alpha;
pub mod bounds;
pub mod lipschitz;
