//! Self-test: the real workspace must lint clean under the real
//! `lint.toml`. This is the same pass CI runs as `cargo xtask lint`,
//! executed in-process so `cargo test` alone catches regressions.

use std::path::Path;

#[test]
fn workspace_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask sits one level below the repo root")
        .to_path_buf();
    let toml = std::fs::read_to_string(root.join("lint.toml")).expect("read lint.toml");
    let cfg = xtask::Config::parse(&toml).expect("parse lint.toml");
    let files = xtask::collect_files(&root, &cfg.scan_roots).expect("collect sources");
    assert!(
        files.len() > 50,
        "suspiciously few sources ({}) — scan roots broken?",
        files.len()
    );
    let diags = xtask::lint_sources(&files, &cfg);
    let listing: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
    assert!(
        diags.is_empty(),
        "workspace must lint clean; findings:\n{}",
        listing.join("\n")
    );
}
