//! Self-test: the real workspace must pass BOTH analysis stages under
//! their real configs. These are the same passes CI runs as `cargo
//! xtask lint` and `cargo xtask analyze`, executed in-process so `cargo
//! test` alone catches regressions.

use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask sits one level below the repo root")
        .to_path_buf()
}

#[test]
fn workspace_lints_clean() {
    let root = repo_root();
    let toml = std::fs::read_to_string(root.join("lint.toml")).expect("read lint.toml");
    let cfg = xtask::Config::parse(&toml).expect("parse lint.toml");
    let files = xtask::collect_files(&root, &cfg.scan_roots).expect("collect sources");
    assert!(
        files.len() > 50,
        "suspiciously few sources ({}) — scan roots broken?",
        files.len()
    );
    let diags = xtask::lint_sources(&files, &cfg);
    let listing: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
    assert!(
        diags.is_empty(),
        "workspace must lint clean; findings:\n{}",
        listing.join("\n")
    );
}

#[test]
fn workspace_analyzes_clean() {
    let root = repo_root();
    let toml = std::fs::read_to_string(root.join("analyze.toml")).expect("read analyze.toml");
    let cfg = xtask::AnalyzeConfig::parse(&toml).expect("parse analyze.toml");
    assert!(
        !cfg.cone_entries.is_empty(),
        "panic_cone without entry points checks nothing"
    );
    let files = xtask::collect_files(&root, &cfg.scan_roots).expect("collect sources");
    assert!(
        files.len() > 50,
        "suspiciously few sources ({}) — scan roots broken?",
        files.len()
    );
    let diags = xtask::analyze_sources(&files, &cfg);
    let listing: Vec<String> = diags.iter().map(|d| d.to_string()).collect();
    assert!(
        diags.is_empty(),
        "workspace must analyze clean (fix the code, or suppress with a \
         justified `fmq-analyze: allow(..)` marker); findings:\n{}",
        listing.join("\n")
    );
    // the SARIF serialization of the clean run must still be a valid doc
    let sarif = xtask::sarif::to_sarif(&diags);
    assert!(sarif.contains("\"version\":\"2.1.0\""));
    assert!(sarif.contains("\"results\":[]"));
}
