//! Stage-2 fixture tests: each known-bad snippet under `tests/fixtures/`
//! must trip *exactly one* diagnostic of the expected pass, and the
//! near-miss fixture must trip none. Mirrors `lint_fixtures.rs` — the
//! fixtures are analyzer inputs, not compiled code.

use xtask::{analyze_sources, AnalyzeConfig, Diag};

fn strs(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|x| x.to_string()).collect()
}

/// A self-contained config scoped to the fixture pseudo-paths, mirroring
/// the shape of the real `analyze.toml`.
fn fixture_cfg() -> AnalyzeConfig {
    AnalyzeConfig {
        scan_roots: strs(&["fix"]),
        cone_entries: strs(&["serve_entry", "Step::run*"]),
        cone_index_audited: strs(&["audited_kernel"]),
        lock_guard_fns: strs(&["lock", "workspace"]),
        lock_blocking: strs(&["send", "recv", "join"]),
        lock_indexed: strs(&["slot"]),
        taint_time_paths: strs(&["Instant::now", "SystemTime::now"]),
        taint_time_methods: strs(&["elapsed"]),
        taint_reduction_scope: strs(&["fix/"]),
        taint_reduction_allow: strs(&["ok_bytes"]),
        taint_source_allow: strs(&["Span::*"]),
        taint_source_allow_paths: strs(&["fix/obs/"]),
        taint_sinks: strs(&["write_report", "StepGrid::new"]),
        unsafe_unchecked: strs(&["get_unchecked", "from_raw_parts", "transmute", "assume_init"]),
    }
}

fn analyze_one(path: &str, src: &str) -> Vec<Diag> {
    analyze_sources(&[(path.to_string(), src.to_string())], &fixture_cfg())
}

/// Assert the fixture trips exactly one diagnostic of `rule`, and that
/// its message mentions `needle`.
fn expect_one(path: &str, src: &str, rule: &str, needle: &str) -> Diag {
    let diags = analyze_one(path, src);
    assert_eq!(
        diags.len(),
        1,
        "{path}: expected exactly one diagnostic, got: {diags:#?}"
    );
    let d = diags.into_iter().next().expect("len checked above");
    assert_eq!(d.rule, rule, "{path}: wrong pass: {d}");
    assert!(
        d.msg.contains(needle),
        "{path}: message should mention `{needle}`: {d}"
    );
    d
}

#[test]
fn panic_cone_transitive_unwrap() {
    let d = expect_one(
        "fix/bad_cone_unwrap.rs",
        include_str!("fixtures/bad_cone_unwrap.rs"),
        "panic_cone",
        "unwrap",
    );
    assert!(
        d.msg.contains("serve_entry") && d.msg.contains("helper") && d.msg.contains("decode"),
        "message should carry the entry-to-panic witness chain: {d}"
    );
    assert_eq!(d.line, 14, "diagnostic should anchor at the unwrap line");
}

#[test]
fn lock_order_cycle_through_helpers() {
    let d = expect_one(
        "fix/bad_lock_cycle.rs",
        include_str!("fixtures/bad_lock_cycle.rs"),
        "lock_order",
        "cycle",
    );
    assert!(
        d.msg.contains('a') && d.msg.contains('b') && d.msg.contains("deadlock"),
        "message should name both lock classes: {d}"
    );
}

#[test]
fn det_taint_elapsed_reaches_sink() {
    let d = expect_one(
        "fix/bad_taint_fingerprint.rs",
        include_str!("fixtures/bad_taint_fingerprint.rs"),
        "det_taint",
        "write_report",
    );
    assert!(
        d.msg.contains("elapsed"),
        "message should carry the concrete source witness: {d}"
    );
    assert_eq!(d.line, 8, "diagnostic should anchor at the sink call line");
}

#[test]
fn unsafe_bounds_unannotated_block() {
    let d = expect_one(
        "fix/bad_unsafe_unannotated.rs",
        include_str!("fixtures/bad_unsafe_unannotated.rs"),
        "unsafe_bounds",
        "safety annotation",
    );
    assert_eq!(d.line, 10, "diagnostic should anchor at the unsafe line");
}

#[test]
fn clean_fixture_with_near_misses_is_clean() {
    let diags = analyze_one(
        "fix/good_analyze_clean.rs",
        include_str!("fixtures/good_analyze_clean.rs"),
    );
    assert!(
        diags.is_empty(),
        "good_analyze_clean.rs must analyze clean, got: {diags:#?}"
    );
}

/// The bad fixtures are single-purpose: no fixture may trip a *second*
/// pass, or the "exactly one" contract above silently weakens.
#[test]
fn bad_fixtures_trip_only_their_own_pass() {
    let all = [
        ("fix/bad_cone_unwrap.rs", include_str!("fixtures/bad_cone_unwrap.rs"), "panic_cone"),
        ("fix/bad_lock_cycle.rs", include_str!("fixtures/bad_lock_cycle.rs"), "lock_order"),
        (
            "fix/bad_taint_fingerprint.rs",
            include_str!("fixtures/bad_taint_fingerprint.rs"),
            "det_taint",
        ),
        (
            "fix/bad_unsafe_unannotated.rs",
            include_str!("fixtures/bad_unsafe_unannotated.rs"),
            "unsafe_bounds",
        ),
    ];
    for (path, src, rule) in all {
        for d in analyze_one(path, src) {
            assert_eq!(d.rule, rule, "{path}: unexpected cross-pass finding: {d}");
        }
    }
}

/// Deny-side twins of the near-misses in `good_analyze_clean.rs`: an
/// unguarded divisor and computed indexing inside the cone still trip.
#[test]
fn panic_cone_unguarded_division_and_computed_index() {
    let div = "pub fn serve_entry(x: usize, d: usize) -> usize {\n    x / d\n}\n";
    let d = analyze_one("fix/div.rs", div);
    assert_eq!(d.len(), 1, "got: {d:#?}");
    assert!(d[0].msg.contains("division by unguarded variable"), "{}", d[0]);

    let idx = "pub fn serve_entry(xs: &[u32], k: usize) -> u32 {\n    xs[k + 1]\n}\n";
    let d = analyze_one("fix/idx.rs", idx);
    assert_eq!(d.len(), 1, "got: {d:#?}");
    assert!(d[0].msg.contains("slice indexing"), "{}", d[0]);
}

/// An `allow` without the mandatory `-- why` justification is itself a
/// finding — the suppression grammar is part of the contract.
#[test]
fn unjustified_allow_is_reported() {
    let src = "pub fn serve_entry(xs: &[u32]) -> u32 {\n\
               \x20   // fmq-analyze: allow(panic_cone)\n\
               \x20   *xs.first().unwrap()\n\
               }\n";
    let diags = analyze_one("fix/unjustified.rs", src);
    assert_eq!(diags.len(), 1, "got: {diags:#?}");
    assert!(
        diags[0].msg.contains("without a justification"),
        "bare allow must be its own finding: {}",
        diags[0]
    );
}
