//! Fixture: `.unwrap()` on a request-handling path. Expected: exactly
//! one `panic_safety` diagnostic.

pub fn parse_header(line: &str) -> u32 {
    let n: u32 = line.trim().parse().unwrap();
    n
}
