//! Known-bad: lock-order cycle through helpers. `order_ab` holds `a`
//! while a callee takes `b`; `order_ba` holds `b` while a callee takes
//! `a`. Neither function is wrong on its own — the deadlock only exists
//! in the may-hold-while-acquiring graph across both.

struct Pair {
    a: std::sync::Mutex<u32>,
    b: std::sync::Mutex<u32>,
}

fn order_ab(p: &Pair) {
    let ga = p.a.lock();
    take_b(p);
    drop(ga);
}

fn take_b(p: &Pair) {
    let gb = p.b.lock();
    consume(*gb);
    drop(gb);
}

fn order_ba(p: &Pair) {
    let gb = p.b.lock();
    take_a(p);
    drop(gb);
}

fn take_a(p: &Pair) {
    let ga = p.a.lock();
    consume(*ga);
    drop(ga);
}

fn consume(_x: u32) {}
