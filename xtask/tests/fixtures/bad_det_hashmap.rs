//! Fixture: an unordered container in a file whose iteration order
//! reaches artifacts/wire. Expected: exactly one `determinism`
//! diagnostic (at the single `HashMap` mention).

pub type TileCache = std::collections::HashMap<u32, u32>;

pub fn lookup(cache: &TileCache, k: u32) -> Option<u32> {
    cache.get(&k).copied()
}
