//! Known-bad: an `unsafe` block with no `fmq-analyze: safety` proof.
//! The code happens to be guarded, but the audit trail is the point —
//! an unsound edit here would review exactly like a sound one.

pub fn head_unchecked(xs: &[u32]) -> u32 {
    if xs.is_empty() {
        return 0;
    }
    let p = xs.as_ptr();
    unsafe { *p }
}
