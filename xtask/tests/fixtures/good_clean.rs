//! Fixture: near-misses for every rule. Expected: zero diagnostics.
//!
//! Exercises: `unwrap_or*` (not `unwrap`), an annotated hot fn that is
//! genuinely alloc-free, an allowlisted integer reduction, a suppressed
//! `HashMap` with an inline `fmq-lint: allow(...)` marker, a guard
//! dropped before the blocking call, and panicky code confined to
//! `#[cfg(test)]`.

use std::sync::mpsc::Sender;
use std::sync::Mutex;

// fmq-lint: allow(determinism) -- scratch map, never iterated or serialized
pub type Scratch = std::collections::HashMap<u32, u32>;

#[fmq_macros::no_alloc]
pub fn add_into(out: &mut [f32], x: &[f32]) {
    for (o, v) in out.iter_mut().zip(x) {
        *o += *v;
    }
}

pub fn parse_or_zero(line: &str) -> u32 {
    line.trim().parse().unwrap_or(0)
}

pub fn ok_bytes(rows: &[Vec<f32>]) -> usize {
    rows.iter().map(|r| r.capacity() * 4).sum::<usize>()
}

pub fn pump(counter: &Mutex<u32>, tx: &Sender<u32>) {
    let guard = counter.lock().unwrap_or_else(|p| p.into_inner());
    let n = *guard;
    drop(guard);
    let _ = tx.send(n);
}

#[cfg(test)]
mod tests {
    #[test]
    fn panicky_test_code_is_exempt() {
        let v = vec![1u32, 2, 3];
        assert_eq!(v[0], *v.first().unwrap());
    }
}
