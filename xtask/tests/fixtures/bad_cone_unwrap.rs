//! Known-bad: `.unwrap()` two frames below a serving entry point. The
//! entry itself is spotless — the panic hides in a transitive callee,
//! which is exactly what the file-scoped stage-1 rule could not see.

pub fn serve_entry(xs: &[u32]) -> u32 {
    helper(xs)
}

fn helper(xs: &[u32]) -> u32 {
    decode(xs)
}

fn decode(xs: &[u32]) -> u32 {
    *xs.first().unwrap()
}
