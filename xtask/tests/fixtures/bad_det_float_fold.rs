//! Fixture: a float reduction (`.fold`) inside the reduction-checked
//! scope, in a function not on the allowlist. Expected: exactly one
//! `determinism` diagnostic.

pub fn mean(xs: &[f32]) -> f32 {
    let total = xs.iter().fold(0.0f32, |a, b| a + b);
    total / xs.len().max(1) as f32
}
