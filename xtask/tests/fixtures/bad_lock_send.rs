//! Fixture: a `MutexGuard` held across a channel `send` — the classic
//! shape that deadlocks when the receiver needs the same lock. Expected:
//! exactly one `lock_hygiene` diagnostic.

use std::sync::mpsc::Sender;
use std::sync::Mutex;

pub fn pump(queue: &Mutex<Vec<u32>>, tx: &Sender<u32>) {
    let guard = queue.lock().unwrap_or_else(|p| p.into_inner());
    let n = guard.len() as u32;
    let _ = tx.send(n);
}
