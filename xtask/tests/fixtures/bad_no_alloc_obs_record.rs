//! Fixture: a metric record path enrolled via the `Hist::*` wildcard
//! root that allocates via `format!`. Expected: exactly one `no_alloc`
//! diagnostic.

pub struct Hist {
    name: &'static str,
    count: u64,
}

impl Hist {
    pub fn record(&mut self, v: u64) {
        let label = format!("{}={v}", self.name);
        self.count += label.len() as u64;
    }
}
