//! Fixture: a sweep per-cell sample loop (the shape of
//! `sweep::grid::run_cell_samples`, enrolled by name in the real
//! `lint.toml`) that allocates inside its inner loop via `format!`.
//! Expected: exactly one `no_alloc` diagnostic.

pub fn run_cell(x0: &[f32], batch: usize, out: &mut Vec<f32>) -> usize {
    let mut evals = 0usize;
    for (i, chunk) in x0.chunks(batch).enumerate() {
        let label = format!("cell-{i}");
        evals += label.len();
        for &v in chunk {
            out.push(v.clamp(-1.0, 1.0));
        }
    }
    evals
}
