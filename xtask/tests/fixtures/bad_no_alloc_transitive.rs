//! Fixture: the enrolled root `hot_entry` is itself alloc-free, but it
//! calls a local helper that allocates with `Vec::new`. Expected: exactly
//! one `no_alloc` diagnostic, located at the helper's allocation and
//! attributed through the call chain.

pub fn hot_entry(out: &mut [f32]) {
    helper(out);
}

fn helper(out: &mut [f32]) {
    let mut acc: Vec<f32> = Vec::new();
    acc.extend_from_slice(out);
    for (o, a) in out.iter_mut().zip(&acc) {
        *o = *a;
    }
}
