//! Fixture: a `#[fmq_macros::no_alloc]` function that allocates via the
//! `vec!` macro. Expected: exactly one `no_alloc` diagnostic.

#[fmq_macros::no_alloc]
pub fn hot_step(out: &mut [f32]) {
    let scratch = vec![0.0f32; out.len()];
    for (o, s) in out.iter_mut().zip(&scratch) {
        *o += *s;
    }
}
