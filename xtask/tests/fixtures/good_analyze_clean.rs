//! Near-miss fixture for stage 2: every construct here skirts the edge
//! of an analyze pass and must produce zero findings.
//!
//! panic_cone: guarded divisors (`.max(1)` binding, SCREAMING constant,
//! float-typed division), loop-var and full-range indexing, an audited
//! kernel, a justified suppression, and `?`-style error handling where
//! an `.unwrap()` would be tempting.
//! lock_order: two functions taking `a` then `b` in the *same* order
//! (edges but no cycle), with blocking deferred until the guard drops.
//! det_taint: a tainted value that never reaches a sink, an allow-listed
//! reduction, and an untainted caller of the sink.
//! unsafe_bounds: an `unsafe` block that carries its proof.

use std::collections::BTreeMap;

const LANES: usize = 4;

pub fn serve_entry(xs: &mut [f32], d: usize) -> f32 {
    let d = d.max(1);
    let rows = xs.len() / d;
    let scale = xs.len() as f32 / 2.0;
    let frac = 0.5 / scale;
    let per_lane = rows / LANES;
    for i in 0..xs.len() {
        xs[i] = frac;
    }
    let all = &xs[..];
    checked_head(all) + audited_kernel(all, 0, 0, 1) + suppressed_peek(all) + per_lane as f32
}

fn checked_head(xs: &[f32]) -> f32 {
    match xs.first() {
        Some(v) => *v,
        None => 0.0,
    }
}

fn audited_kernel(xs: &[f32], i: usize, j: usize, w: usize) -> f32 {
    xs[i * w + j]
}

fn suppressed_peek(xs: &[f32]) -> f32 {
    // fmq-analyze: allow(panic_cone) -- fixture: a justified suppression must silence the pass
    xs[0]
}

struct Locks {
    a: std::sync::Mutex<u32>,
    b: std::sync::Mutex<u32>,
}

fn ordered_one(l: &Locks, tx: &std::sync::mpsc::Sender<u32>) {
    let ga = l.a.lock();
    grab_b(l);
    drop(ga);
    tx.send(1).ok();
}

fn ordered_two(l: &Locks) {
    let ga = l.a.lock();
    grab_b(l);
    drop(ga);
}

fn grab_b(l: &Locks) {
    let gb = l.b.lock();
    consume(*gb);
    drop(gb);
}

fn consume(_x: u32) {}

fn timed_probe(start: std::time::Instant) -> u64 {
    start.elapsed().as_nanos() as u64
}

fn ok_bytes(xs: &[f32]) -> f32 {
    xs.iter().sum()
}

fn clean_writer(out: &mut Vec<u8>, tags: &BTreeMap<u32, u8>) {
    for (&k, &v) in tags {
        write_report(out, (k as u64) << 8 | v as u64);
    }
}

fn write_report(out: &mut Vec<u8>, stamp: u64) {
    out.push((stamp & 0xff) as u8);
}

fn head_or_zero(xs: &[u32]) -> u32 {
    if xs.is_empty() {
        return 0;
    }
    // fmq-analyze: safety -- emptiness is checked above, so `as_ptr` reads in-bounds
    unsafe { *xs.as_ptr() }
}
