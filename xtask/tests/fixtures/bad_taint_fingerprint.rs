//! Known-bad: a wall-clock read flowing into an artifact sink. The
//! `.elapsed()` seeds taint in `fingerprint`, which then hands the value
//! to the configured sink `write_report` — a byte-stable artifact now
//! depends on scheduling.

pub fn fingerprint(start: std::time::Instant, out: &mut Vec<u8>) {
    let wall = start.elapsed();
    write_report(out, wall.as_nanos() as u64);
}

fn write_report(out: &mut Vec<u8>, stamp: u64) {
    out.push((stamp & 0xff) as u8);
}
