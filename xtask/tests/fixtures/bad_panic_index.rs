//! Fixture: direct slice indexing on a request-handling path (panics on
//! out-of-bounds input). Expected: exactly one `panic_safety` diagnostic.

pub fn first_row(rows: &[f32], d: usize) -> f32 {
    let head = rows[d];
    head
}
