//! Fixture tests: each known-bad snippet under `tests/fixtures/` must
//! trip *exactly one* diagnostic of the expected rule, and the clean
//! fixture (all near-misses) must trip none. The fixtures are lint
//! inputs, not compiled code — they live in a subdirectory so cargo
//! does not build them as test targets.

use xtask::{lint_sources, Config, Diag};

fn strs(xs: &[&str]) -> Vec<String> {
    xs.iter().map(|x| x.to_string()).collect()
}

/// A self-contained config scoped to the fixture pseudo-paths, mirroring
/// the shape of the real `lint.toml`.
fn fixture_cfg() -> Config {
    Config {
        scan_roots: strs(&["fix"]),
        no_alloc_roots: strs(&["hot_entry", "Hist::*", "run_cell"]),
        no_alloc_allow: vec![],
        no_alloc_forbidden_calls: strs(&["to_vec", "collect", "clone", "to_owned", "to_string"]),
        no_alloc_forbidden_macros: strs(&["vec", "format"]),
        no_alloc_forbidden_paths: strs(&["Vec::new", "Box::new", "String::new", "Vec::from"]),
        det_ordered: strs(&["fix/bad_det_hashmap.rs", "fix/good_clean.rs"]),
        det_reduction_scope: strs(&["fix/"]),
        det_reduction_allow: strs(&["ok_bytes"]),
        panic_paths: strs(&[
            "fix/bad_panic_unwrap.rs",
            "fix/bad_panic_index.rs",
            "fix/good_clean.rs",
        ]),
        lock_paths: strs(&["fix/bad_lock_send.rs", "fix/good_clean.rs"]),
        lock_guard_fns: strs(&["lock"]),
        lock_blocking: strs(&["send", "recv"]),
    }
}

fn lint_one(path: &str, src: &str) -> Vec<Diag> {
    lint_sources(&[(path.to_string(), src.to_string())], &fixture_cfg())
}

/// Assert the fixture trips exactly one diagnostic of `rule`, and that
/// its message mentions `needle`.
fn expect_one(path: &str, src: &str, rule: &str, needle: &str) -> Diag {
    let diags = lint_one(path, src);
    assert_eq!(
        diags.len(),
        1,
        "{path}: expected exactly one diagnostic, got: {diags:#?}"
    );
    let d = diags.into_iter().next().expect("len checked above");
    assert_eq!(d.rule, rule, "{path}: wrong rule: {d}");
    assert!(
        d.msg.contains(needle),
        "{path}: message should mention `{needle}`: {d}"
    );
    d
}

#[test]
fn no_alloc_vec_macro_in_annotated_fn() {
    let d = expect_one(
        "fix/bad_no_alloc_vec.rs",
        include_str!("fixtures/bad_no_alloc_vec.rs"),
        "no_alloc",
        "`vec!`",
    );
    assert_eq!(d.line, 6, "diagnostic should anchor at the vec! line");
}

#[test]
fn no_alloc_transitive_callee_allocation() {
    let d = expect_one(
        "fix/bad_no_alloc_transitive.rs",
        include_str!("fixtures/bad_no_alloc_transitive.rs"),
        "no_alloc",
        "`Vec::new`",
    );
    assert!(
        d.msg.contains("hot_entry") && d.msg.contains("helper"),
        "message should show the call chain from the root: {d}"
    );
}

#[test]
fn no_alloc_format_in_wildcard_rooted_record_path() {
    let d = expect_one(
        "fix/bad_no_alloc_obs_record.rs",
        include_str!("fixtures/bad_no_alloc_obs_record.rs"),
        "no_alloc",
        "`format!`",
    );
    assert_eq!(d.line, 12, "diagnostic should anchor at the format! line");
    assert!(
        d.msg.contains("Hist::record"),
        "wildcard root must qualify the method: {d}"
    );
}

/// The sweep's per-cell hot loop is enrolled by bare name in the real
/// `lint.toml`; this fixture proves an allocating inner loop of that
/// shape is caught (push/chunks stay permitted, `format!` trips).
#[test]
fn no_alloc_allocating_sweep_cell_loop() {
    let d = expect_one(
        "fix/bad_no_alloc_sweep_cell.rs",
        include_str!("fixtures/bad_no_alloc_sweep_cell.rs"),
        "no_alloc",
        "`format!`",
    );
    assert_eq!(d.line, 9, "diagnostic should anchor at the format! line");
    assert!(
        d.msg.contains("run_cell"),
        "message should name the rooted fn: {d}"
    );
}

#[test]
fn determinism_hashmap_in_ordered_file() {
    let d = expect_one(
        "fix/bad_det_hashmap.rs",
        include_str!("fixtures/bad_det_hashmap.rs"),
        "determinism",
        "BTreeMap",
    );
    assert_eq!(d.line, 5);
}

#[test]
fn determinism_float_fold_in_scope() {
    expect_one(
        "fix/bad_det_float_fold.rs",
        include_str!("fixtures/bad_det_float_fold.rs"),
        "determinism",
        "fold",
    );
}

#[test]
fn panic_safety_unwrap() {
    expect_one(
        "fix/bad_panic_unwrap.rs",
        include_str!("fixtures/bad_panic_unwrap.rs"),
        "panic_safety",
        "unwrap",
    );
}

#[test]
fn panic_safety_slice_indexing() {
    let d = expect_one(
        "fix/bad_panic_index.rs",
        include_str!("fixtures/bad_panic_index.rs"),
        "panic_safety",
        "indexing",
    );
    assert_eq!(d.line, 5);
}

#[test]
fn lock_hygiene_guard_across_send() {
    let d = expect_one(
        "fix/bad_lock_send.rs",
        include_str!("fixtures/bad_lock_send.rs"),
        "lock_hygiene",
        "send",
    );
    assert!(d.msg.contains("guard"), "message should name the guard: {d}");
}

#[test]
fn clean_fixture_with_near_misses_is_clean() {
    let diags = lint_one("fix/good_clean.rs", include_str!("fixtures/good_clean.rs"));
    assert!(
        diags.is_empty(),
        "good_clean.rs must lint clean, got: {diags:#?}"
    );
}

/// The bad fixtures are single-purpose: no fixture may trip a *second*
/// rule, or the "exactly one" contract above silently weakens.
#[test]
fn bad_fixtures_trip_only_their_own_rule() {
    let all = [
        ("fix/bad_no_alloc_vec.rs", include_str!("fixtures/bad_no_alloc_vec.rs"), "no_alloc"),
        (
            "fix/bad_no_alloc_transitive.rs",
            include_str!("fixtures/bad_no_alloc_transitive.rs"),
            "no_alloc",
        ),
        (
            "fix/bad_no_alloc_obs_record.rs",
            include_str!("fixtures/bad_no_alloc_obs_record.rs"),
            "no_alloc",
        ),
        (
            "fix/bad_no_alloc_sweep_cell.rs",
            include_str!("fixtures/bad_no_alloc_sweep_cell.rs"),
            "no_alloc",
        ),
        ("fix/bad_det_hashmap.rs", include_str!("fixtures/bad_det_hashmap.rs"), "determinism"),
        (
            "fix/bad_det_float_fold.rs",
            include_str!("fixtures/bad_det_float_fold.rs"),
            "determinism",
        ),
        ("fix/bad_panic_unwrap.rs", include_str!("fixtures/bad_panic_unwrap.rs"), "panic_safety"),
        ("fix/bad_panic_index.rs", include_str!("fixtures/bad_panic_index.rs"), "panic_safety"),
        ("fix/bad_lock_send.rs", include_str!("fixtures/bad_lock_send.rs"), "lock_hygiene"),
    ];
    for (path, src, rule) in all {
        for d in lint_one(path, src) {
            assert_eq!(d.rule, rule, "{path}: unexpected cross-rule finding: {d}");
        }
    }
}
