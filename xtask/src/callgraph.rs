//! Whole-workspace call graph over the parsed `fn` items.
//!
//! `cargo xtask analyze` reasons about *reachability* — which code a
//! serving entry point can transitively execute, which locks a callee may
//! acquire, where a nondeterministic value can flow. This module builds
//! the graph those passes share: every non-test `fn` item in the scanned
//! files becomes a node, and every call site is resolved to the local
//! definitions it may target.
//!
//! Resolution is deliberately an over-approximation (the passes deny, so
//! missing an edge is worse than adding one):
//!
//! - `Type::name(...)` resolves to every def with that qualified name
//!   (`Self::name` is rewritten against the enclosing `impl` first);
//! - `.name(...)` method calls resolve to every *method* def with that
//!   bare name, unless the receiver is literally `self` and the enclosing
//!   impl defines `Type::name` — then the receiver pins the target;
//! - `mod::name(...)` / `crate::x::name(...)` module-qualified calls
//!   (lowercase path head) fall back to every free fn named `name` —
//!   we do not track the module tree, only who might be meant;
//! - `name(...)` plain calls resolve to every free fn with that name;
//! - anything that resolves to no local def is external (std) and adds
//!   no edge.
//!
//! All resolution is *crate-scoped*: a call inside `rust/` never edges
//! into `xtask/` or `fmq-macros/` (and vice versa) — the crates are not
//! linked together, so a same-named fn in another crate is a different
//! function, and keeping the edge would drag e.g. the analyzer's own
//! helpers into the serving panic cone.
//!
//! Trait objects fall out naturally: `engine.velocity_into(...)` edges to
//! every local `velocity_into` method, which is exactly the dynamic
//! dispatch set the passes must assume.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::lexer::TokKind;
use crate::parse::ParsedFile;
use crate::rules::{calls_in, Call};

/// Node id: index into [`Graph::nodes`].
pub type NodeId = usize;

/// Crate key of a scanned path: the first path segment (`rust`, `xtask`,
/// `fmq-macros`). Resolution never crosses crate keys. A bare filename
/// (no separator — unit-test inputs) keys to `""` so single-crate test
/// graphs resolve freely.
fn crate_key(path: &str) -> &str {
    match path.split_once('/') {
        Some((head, _)) => head,
        None => "",
    }
}

/// One graph node: `(file index, fn index)` into the parsed file list.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DefRef {
    pub file: usize,
    pub fn_idx: usize,
}

/// The resolved call graph.
pub struct Graph {
    pub nodes: Vec<DefRef>,
    /// Forward edges, deduplicated: callees[u] = nodes u may call.
    pub callees: Vec<Vec<NodeId>>,
    by_qual: BTreeMap<String, Vec<NodeId>>,
    by_name: BTreeMap<String, Vec<NodeId>>,
    /// Defs with an owning type (qual != name), by bare name.
    methods_by_name: BTreeMap<String, Vec<NodeId>>,
}

impl Graph {
    /// Build the graph over every non-test fn item in `files`.
    pub fn build(files: &[ParsedFile]) -> Graph {
        let mut nodes = Vec::new();
        let mut by_name: BTreeMap<String, Vec<NodeId>> = BTreeMap::new();
        let mut by_qual: BTreeMap<String, Vec<NodeId>> = BTreeMap::new();
        let mut methods_by_name: BTreeMap<String, Vec<NodeId>> = BTreeMap::new();
        for (fi, f) in files.iter().enumerate() {
            for (di, d) in f.fns.iter().enumerate() {
                if d.is_test {
                    continue;
                }
                let id = nodes.len();
                nodes.push(DefRef { file: fi, fn_idx: di });
                by_qual.entry(d.qual.clone()).or_default().push(id);
                if d.qual == d.name {
                    by_name.entry(d.name.clone()).or_default().push(id);
                } else {
                    methods_by_name.entry(d.name.clone()).or_default().push(id);
                }
            }
        }

        let mut g = Graph {
            nodes,
            callees: Vec::new(),
            by_qual,
            by_name,
            methods_by_name,
        };
        let mut callees: Vec<Vec<NodeId>> = vec![Vec::new(); g.nodes.len()];
        for (u, c) in callees.iter_mut().enumerate() {
            let nref = g.nodes[u];
            let f = &files[nref.file];
            let Some(body) = f.fns[nref.fn_idx].body else { continue };
            let mut out: BTreeSet<NodeId> = BTreeSet::new();
            for call in calls_in(&f.lexed.toks, body) {
                out.extend(g.resolve(files, u, &call));
            }
            out.remove(&u); // direct recursion adds nothing to reachability
            *c = out.into_iter().collect();
        }
        g.callees = callees;
        g
    }

    /// Keep only candidates from the caller's crate (see module docs).
    fn same_crate(&self, files: &[ParsedFile], caller: NodeId, cands: Vec<NodeId>) -> Vec<NodeId> {
        let ck = crate_key(&files[self.nodes[caller].file].path);
        cands
            .into_iter()
            .filter(|&v| crate_key(&files[self.nodes[v].file].path) == ck)
            .collect()
    }

    /// Resolve one call site inside node `caller` to the local defs it
    /// may target (empty = external).
    pub fn resolve(&self, files: &[ParsedFile], caller: NodeId, call: &Call) -> Vec<NodeId> {
        if call.is_macro {
            return Vec::new();
        }
        let nref = self.nodes[caller];
        let f = &files[nref.file];
        let d = &f.fns[nref.fn_idx];
        // the enclosing type, for `Self::` and `self.` resolution
        let owner = d.qual.strip_suffix(&format!("::{}", d.name)).unwrap_or("");
        if let Some(q) = &call.qual {
            let q = match q.strip_prefix("Self::") {
                Some(rest) if !owner.is_empty() => format!("{owner}::{rest}"),
                _ => q.clone(),
            };
            let mut cands = self.by_qual.get(&q).cloned().unwrap_or_default();
            if cands.is_empty() {
                // module-qualified free-fn call (`blocked::plan_stripe`,
                // `crate::io::save`): the path head is a module, not a
                // type, so match the bare fn name instead. Heads that
                // start lowercase (or `_`) are modules by Rust naming
                // convention; `Type::name` paths never take this branch.
                let head = q.split("::").next().unwrap_or("");
                if head
                    .chars()
                    .next()
                    .is_some_and(|c| c.is_lowercase() || c == '_')
                {
                    cands = self.by_name.get(call.name.as_str()).cloned().unwrap_or_default();
                }
            }
            return self.same_crate(files, caller, cands);
        }
        if call.is_method {
            // receiver heuristic: `self.name(...)` inside `impl Owner`
            // pins `Owner::name` when it exists
            let toks = &f.lexed.toks;
            let recv_is_self = call.at >= 2
                && toks[call.at - 2].kind == TokKind::Ident
                && toks[call.at - 2].text == "self";
            if recv_is_self && !owner.is_empty() {
                if let Some(ts) = self.by_qual.get(&format!("{owner}::{}", call.name)) {
                    let ts = self.same_crate(files, caller, ts.clone());
                    if !ts.is_empty() {
                        return ts;
                    }
                }
            }
            let cands = self
                .methods_by_name
                .get(call.name.as_str())
                .cloned()
                .unwrap_or_default();
            return self.same_crate(files, caller, cands);
        }
        let cands = self
            .by_name
            .get(call.name.as_str())
            .cloned()
            .unwrap_or_default();
        self.same_crate(files, caller, cands)
    }

    /// Nodes matching an entry/sink/audit pattern: `name` (free fn or any
    /// def with that bare name), `Type::name` (exact), or a `prefix*`
    /// wildcard over qualified names (`Batcher::*`, `EngineStep::run*`).
    pub fn matching(&self, files: &[ParsedFile], pattern: &str) -> Vec<NodeId> {
        if let Some(prefix) = pattern.strip_suffix('*') {
            return self
                .by_qual
                .iter()
                .filter(|(q, _)| q.starts_with(prefix))
                .flat_map(|(_, ids)| ids.iter().copied())
                .collect();
        }
        if pattern.contains("::") {
            return self.by_qual.get(pattern).cloned().unwrap_or_default();
        }
        self.nodes
            .iter()
            .enumerate()
            .filter(|&(_, n)| files[n.file].fns[n.fn_idx].name == pattern)
            .map(|(id, _)| id)
            .collect()
    }

    /// Cycle-safe transitive closure from `roots`. Returns, for every
    /// reachable node, the node it was first reached from (`None` for the
    /// roots themselves) — enough to reconstruct a witness chain.
    pub fn reachable(&self, roots: &[NodeId]) -> BTreeMap<NodeId, Option<NodeId>> {
        let mut seen: BTreeMap<NodeId, Option<NodeId>> = BTreeMap::new();
        let mut queue: VecDeque<NodeId> = VecDeque::new();
        for &r in roots {
            if !seen.contains_key(&r) {
                seen.insert(r, None);
                queue.push_back(r);
            }
        }
        while let Some(u) = queue.pop_front() {
            for &v in &self.callees[u] {
                if let std::collections::btree_map::Entry::Vacant(e) = seen.entry(v) {
                    e.insert(Some(u));
                    queue.push_back(v);
                }
            }
        }
        seen
    }

    /// Witness chain of qualified names from a root down to `node`, given
    /// the parent map from [`Graph::reachable`].
    pub fn chain(
        &self,
        files: &[ParsedFile],
        parents: &BTreeMap<NodeId, Option<NodeId>>,
        node: NodeId,
    ) -> Vec<String> {
        let mut rev = Vec::new();
        let mut cur = Some(node);
        while let Some(u) = cur {
            rev.push(self.qual(files, u).to_string());
            cur = parents.get(&u).copied().flatten();
            if rev.len() > self.nodes.len() {
                break; // defensive: parent maps from `reachable` are acyclic
            }
        }
        rev.reverse();
        rev
    }

    /// Qualified name of a node.
    pub fn qual<'a>(&self, files: &'a [ParsedFile], id: NodeId) -> &'a str {
        let n = self.nodes[id];
        &files[n.file].fns[n.fn_idx].qual
    }

    /// Fixpoint propagation of a boolean property from callees to
    /// callers: `out[u]` starts as `seed[u]` and becomes true when any
    /// callee is true. Cycle-safe (monotone fixpoint, at most |V| rounds).
    pub fn propagate_up(&self, seed: &[bool]) -> Vec<bool> {
        let mut out = seed.to_vec();
        let mut changed = true;
        while changed {
            changed = false;
            for u in 0..self.nodes.len() {
                if out[u] {
                    continue;
                }
                if self.callees[u].iter().any(|&v| out[v]) {
                    out[u] = true;
                    changed = true;
                }
            }
        }
        out
    }

    /// For each node, the callee that first made `propagate_up` true for
    /// it (`None` for seeds and untouched nodes) — the witness edge for
    /// taint/blocking chains.
    pub fn propagate_up_witness(&self, seed: &[bool]) -> (Vec<bool>, Vec<Option<NodeId>>) {
        let mut out = seed.to_vec();
        let mut via: Vec<Option<NodeId>> = vec![None; self.nodes.len()];
        let mut changed = true;
        while changed {
            changed = false;
            for u in 0..self.nodes.len() {
                if out[u] {
                    continue;
                }
                if let Some(&v) = self.callees[u].iter().find(|&&v| out[v]) {
                    out[u] = true;
                    via[u] = Some(v);
                    changed = true;
                }
            }
        }
        (out, via)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;
    use crate::parse::parse;

    fn graph_of(sources: &[(&str, &str)]) -> (Vec<ParsedFile>, Graph) {
        let files: Vec<ParsedFile> = sources
            .iter()
            .map(|(p, s)| parse(p, lex(s)))
            .collect();
        let g = Graph::build(&files);
        (files, g)
    }

    fn reach_quals(files: &[ParsedFile], g: &Graph, entry: &str) -> Vec<String> {
        let roots = g.matching(files, entry);
        g.reachable(&roots)
            .keys()
            .map(|&id| g.qual(files, id).to_string())
            .collect()
    }

    #[test]
    fn trait_method_disambiguation() {
        // `self.step()` inside `impl Euler` must pin `Euler::step`, not
        // pull in `Heun::step`; an unpinned `obj.step()` must take both.
        let src = r#"
            impl Euler { fn step(&self) { bad_euler() } fn run(&self) { self.step() } }
            impl Heun { fn step(&self) { bad_heun() } }
            fn drive(s: &dyn Solver) { s.step() }
            fn bad_euler() {}
            fn bad_heun() {}
        "#;
        let (files, g) = graph_of(&[("a.rs", src)]);
        let from_run = reach_quals(&files, &g, "Euler::run");
        assert!(from_run.contains(&"Euler::step".to_string()));
        assert!(from_run.contains(&"bad_euler".to_string()));
        assert!(
            !from_run.contains(&"Heun::step".to_string()),
            "self-receiver must pin the enclosing impl: {from_run:?}"
        );
        let from_drive = reach_quals(&files, &g, "drive");
        assert!(from_drive.contains(&"Euler::step".to_string()));
        assert!(from_drive.contains(&"Heun::step".to_string()));
    }

    #[test]
    fn cross_module_and_cross_file_resolution() {
        // plain calls and `Type::name` paths resolve across files; a
        // qualified call that resolves nowhere locally adds no edge
        let a = r#"
            pub fn entry() { helper(); Codec::pack(1); Vec::with_capacity(4); }
        "#;
        let b = r#"
            pub mod inner {
                pub fn helper() { leaf() }
                pub fn leaf() {}
            }
            impl Codec { pub fn pack(x: u32) {} }
        "#;
        let (files, g) = graph_of(&[("a.rs", a), ("b.rs", b)]);
        let r = reach_quals(&files, &g, "entry");
        assert!(r.contains(&"helper".to_string()));
        assert!(r.contains(&"leaf".to_string()));
        assert!(r.contains(&"Codec::pack".to_string()));
        assert_eq!(r.len(), 4, "external Vec::with_capacity must not resolve: {r:?}");
    }

    #[test]
    fn self_qualified_calls_resolve_against_enclosing_impl() {
        let src = r#"
            impl Grid { fn new() { Self::fill() } fn fill() { sink() } }
            fn sink() {}
        "#;
        let (files, g) = graph_of(&[("a.rs", src)]);
        let r = reach_quals(&files, &g, "Grid::new");
        assert!(r.contains(&"Grid::fill".to_string()));
        assert!(r.contains(&"sink".to_string()));
    }

    #[test]
    fn recursion_and_cycles_terminate() {
        // direct recursion, mutual recursion, and a 3-cycle: reachability
        // and upward propagation must terminate and still be complete
        let src = r#"
            fn entry() { ping() }
            fn ping() { pong(); ping() }
            fn pong() { ping(); tri_a() }
            fn tri_a() { tri_b() }
            fn tri_b() { tri_c() }
            fn tri_c() { tri_a(); deep() }
            fn deep() {}
        "#;
        let (files, g) = graph_of(&[("a.rs", src)]);
        let r = reach_quals(&files, &g, "entry");
        for f in ["ping", "pong", "tri_a", "tri_b", "tri_c", "deep"] {
            assert!(r.contains(&f.to_string()), "missing {f}: {r:?}");
        }
        // propagate deep's seed back up through the cycles
        let mut seed = vec![false; g.nodes.len()];
        let deep = g.matching(&files, "deep");
        seed[deep[0]] = true;
        let up = g.propagate_up(&seed);
        let entry = g.matching(&files, "entry");
        assert!(up[entry[0]], "seed must propagate through cycles to the entry");
    }

    #[test]
    fn wildcard_and_prefix_entry_patterns() {
        let src = r#"
            impl Batcher { fn submit(&self) {} fn next_batch(&self) {} }
            impl EngineStep { fn run(&self) {} fn run_solver(&self) {} fn other(&self) {} }
        "#;
        let (files, g) = graph_of(&[("a.rs", src)]);
        assert_eq!(g.matching(&files, "Batcher::*").len(), 2);
        assert_eq!(g.matching(&files, "EngineStep::run*").len(), 2);
        assert_eq!(g.matching(&files, "EngineStep::run").len(), 1);
    }

    #[test]
    fn test_code_is_outside_the_graph() {
        let src = r#"
            fn entry() { helper() }
            fn helper() {}
            #[cfg(test)]
            mod tests {
                fn entry() { panic!("test-only twin") }
            }
        "#;
        let (files, g) = graph_of(&[("a.rs", src)]);
        assert_eq!(g.matching(&files, "entry").len(), 1);
    }

    #[test]
    fn module_qualified_calls_fall_back_to_bare_fn_name() {
        // `blocked::plan_stripe(...)`-style calls: the path head is a
        // module (lowercase), so the bare fn name resolves; `Codec::pack`
        // (uppercase head = a type) must NOT fall back to a free fn twin.
        let src = r#"
            pub fn entry() { blocked::plan_stripe(); crate::io::save(); Codec::pack(); }
            pub fn plan_stripe() { leaf() }
            pub fn save() {}
            pub fn pack() {}
            pub fn leaf() {}
        "#;
        let (files, g) = graph_of(&[("a.rs", src)]);
        let r = reach_quals(&files, &g, "entry");
        assert!(r.contains(&"plan_stripe".to_string()), "{r:?}");
        assert!(r.contains(&"save".to_string()), "crate:: paths: {r:?}");
        assert!(r.contains(&"leaf".to_string()), "transitive: {r:?}");
        assert!(
            !r.contains(&"pack".to_string()),
            "Type::name must stay exact, no bare-name fallback: {r:?}"
        );
    }

    #[test]
    fn resolution_never_crosses_crates() {
        // same fn names in two crates: edges stay within the caller's
        // first path segment, so the xtask twin is unreachable
        let a = r#"
            pub fn entry() { helper(); t.shared_method(); }
            pub fn helper() {}
            impl Real { fn shared_method(&self) { real_leaf() } }
            pub fn real_leaf() {}
        "#;
        let b = r#"
            pub fn helper() { other_leaf() }
            impl Fake { fn shared_method(&self) { other_leaf() } }
            pub fn other_leaf() {}
        "#;
        let (files, g) = graph_of(&[("rust/src/a.rs", a), ("xtask/src/b.rs", b)]);
        let r = reach_quals(&files, &g, "entry");
        assert!(r.contains(&"Real::shared_method".to_string()), "{r:?}");
        assert!(r.contains(&"real_leaf".to_string()), "{r:?}");
        assert!(
            !r.contains(&"other_leaf".to_string()) && !r.contains(&"Fake::shared_method".to_string()),
            "cross-crate twins must not edge: {r:?}"
        );
    }

    #[test]
    fn chains_reconstruct_a_root_to_node_witness() {
        let src = "fn a() { b() } fn b() { c() } fn c() {}";
        let (files, g) = graph_of(&[("a.rs", src)]);
        let roots = g.matching(&files, "a");
        let parents = g.reachable(&roots);
        let c = g.matching(&files, "c")[0];
        assert_eq!(g.chain(&files, &parents, c), vec!["a", "b", "c"]);
    }
}
