//! `lint.toml` loading.
//!
//! Parses the minimal TOML subset the config actually uses — `[section]`
//! headers, `key = "string"`, and (possibly multiline) `key = ["a", "b"]`
//! string arrays, with `#` comments — so xtask needs no TOML crate and
//! keeps building offline. Unknown sections/keys are rejected so typos in
//! `lint.toml` fail loudly instead of silently disabling a rule.

use anyhow::{bail, Context, Result};

/// Parsed lint configuration. Field groups mirror `lint.toml` sections.
#[derive(Debug, Clone, Default)]
pub struct Config {
    /// Directories (repo-relative) whose `.rs` files are linted.
    pub scan_roots: Vec<String>,

    /// no_alloc: functions enrolled by qualified (`Type::name`) or bare
    /// name, in addition to `#[fmq_macros::no_alloc]` annotations.
    pub no_alloc_roots: Vec<String>,
    /// no_alloc: trusted leaf functions the transitive walk does not
    /// enter (documented cold paths: cache fill, autotune warm-up).
    pub no_alloc_allow: Vec<String>,
    /// no_alloc: forbidden method/function call names (`collect`, ...).
    pub no_alloc_forbidden_calls: Vec<String>,
    /// no_alloc: forbidden macro names (`vec`, `format`).
    pub no_alloc_forbidden_macros: Vec<String>,
    /// no_alloc: forbidden `Type::fn` paths (`Vec::new`, `Box::new`).
    pub no_alloc_forbidden_paths: Vec<String>,

    /// determinism: files whose iteration order reaches packed artifacts,
    /// tuning keys, or the wire — `HashMap`/`HashSet` are denied there.
    pub det_ordered: Vec<String>,
    /// determinism: path prefixes where float reductions are checked.
    pub det_reduction_scope: Vec<String>,
    /// determinism: functions allowed to use `.sum()`/`.fold()` (integer
    /// byte counts and other order-independent reductions).
    pub det_reduction_allow: Vec<String>,

    /// panic_safety: files where unwrap/expect/panic!/indexing are denied.
    pub panic_paths: Vec<String>,

    /// lock_hygiene: files scanned for guards held across blocking calls.
    pub lock_paths: Vec<String>,
    /// lock_hygiene: methods that return a guard (`lock`, `workspace`).
    pub lock_guard_fns: Vec<String>,
    /// lock_hygiene: blocking call names (`send`, `recv`, `join`, ...).
    pub lock_blocking: Vec<String>,
}

impl Config {
    /// Parse a `lint.toml` document.
    pub fn parse(src: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        let mut lines = src.lines().enumerate().peekable();
        while let Some((ln, raw)) = lines.next() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("lint.toml:{}: malformed section header", ln + 1);
                };
                section = name.trim().to_string();
                match section.as_str() {
                    "scan" | "no_alloc" | "determinism" | "panic_safety" | "lock_hygiene" => {}
                    other => bail!("lint.toml:{}: unknown section [{other}]", ln + 1),
                }
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("lint.toml:{}: expected `key = value`", ln + 1);
            };
            let key = line[..eq].trim().to_string();
            let mut value = line[eq + 1..].trim().to_string();
            // multiline array: keep consuming until the closing bracket
            if value.starts_with('[') {
                while !value.contains(']') {
                    let Some((_, more)) = lines.next() else {
                        bail!("lint.toml:{}: unterminated array for `{key}`", ln + 1);
                    };
                    value.push(' ');
                    value.push_str(strip_comment(more).trim());
                }
            }
            let items = parse_value(&value)
                .with_context(|| format!("lint.toml:{}: bad value for `{key}`", ln + 1))?;
            let slot = match (section.as_str(), key.as_str()) {
                ("scan", "roots") => &mut cfg.scan_roots,
                ("no_alloc", "roots") => &mut cfg.no_alloc_roots,
                ("no_alloc", "allow") => &mut cfg.no_alloc_allow,
                ("no_alloc", "forbidden_calls") => &mut cfg.no_alloc_forbidden_calls,
                ("no_alloc", "forbidden_macros") => &mut cfg.no_alloc_forbidden_macros,
                ("no_alloc", "forbidden_paths") => &mut cfg.no_alloc_forbidden_paths,
                ("determinism", "ordered") => &mut cfg.det_ordered,
                ("determinism", "reduction_scope") => &mut cfg.det_reduction_scope,
                ("determinism", "reduction_allow") => &mut cfg.det_reduction_allow,
                ("panic_safety", "paths") => &mut cfg.panic_paths,
                ("lock_hygiene", "paths") => &mut cfg.lock_paths,
                ("lock_hygiene", "guard_fns") => &mut cfg.lock_guard_fns,
                ("lock_hygiene", "blocking") => &mut cfg.lock_blocking,
                (s, k) => bail!("lint.toml:{}: unknown key `{k}` in [{s}]", ln + 1),
            };
            slot.extend(items);
        }
        Ok(cfg)
    }

    /// Does `path` (repo-relative, `/`-separated) fall under any entry of
    /// `pats`? An entry ending in `/` is a directory prefix; otherwise it
    /// must match the path exactly or be its suffix (so fixtures can use
    /// short labels).
    pub fn path_in(path: &str, pats: &[String]) -> bool {
        pats.iter().any(|p| {
            if p.ends_with('/') {
                path.starts_with(p.as_str())
            } else {
                path == p || path.ends_with(&format!("/{p}")) || path.starts_with(p.as_str())
            }
        })
    }
}

/// Drop a `#` comment, respecting `"` quoting.
pub(crate) fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse `"x"` or `["a", "b", ...]` into a list of strings.
pub(crate) fn parse_value(v: &str) -> Result<Vec<String>> {
    let v = v.trim();
    if let Some(body) = v.strip_prefix('[') {
        let Some(body) = body.strip_suffix(']') else {
            bail!("unterminated array");
        };
        let mut out = Vec::new();
        for item in body.split(',') {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            out.push(unquote(item)?);
        }
        return Ok(out);
    }
    Ok(vec![unquote(v)?])
}

fn unquote(s: &str) -> Result<String> {
    let s = s.trim();
    match s.strip_prefix('"').and_then(|r| r.strip_suffix('"')) {
        Some(inner) => Ok(inner.to_string()),
        None => bail!("expected a double-quoted string, got `{s}`"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_scalars_and_multiline_arrays() {
        let src = r#"
# comment
[scan]
roots = ["rust/src"]

[no_alloc]
roots = [
    "LutModel::velocity_into",  # trailing comment
    "matmul_stripe",
]
allow = ["row"]

[panic_safety]
paths = ["rust/src/main.rs"]
"#;
        let c = Config::parse(src).unwrap();
        assert_eq!(c.scan_roots, vec!["rust/src"]);
        assert_eq!(
            c.no_alloc_roots,
            vec!["LutModel::velocity_into", "matmul_stripe"]
        );
        assert_eq!(c.no_alloc_allow, vec!["row"]);
        assert_eq!(c.panic_paths, vec!["rust/src/main.rs"]);
    }

    #[test]
    fn unknown_keys_fail_loudly() {
        assert!(Config::parse("[scan]\nrootz = [\"x\"]").is_err());
        assert!(Config::parse("[nope]\n").is_err());
    }

    #[test]
    fn path_matching_prefix_and_exact() {
        let pats = vec!["rust/src/engine/".to_string(), "rust/src/main.rs".to_string()];
        assert!(Config::path_in("rust/src/engine/pool.rs", &pats));
        assert!(Config::path_in("rust/src/main.rs", &pats));
        assert!(!Config::path_in("rust/src/flow/ode.rs", &pats));
    }
}
