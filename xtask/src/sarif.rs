//! SARIF 2.1.0 serialization of analyze diagnostics.
//!
//! The output is the minimal valid shape GitHub code scanning consumes:
//! one run, a tool driver declaring the four rules, and one `result` per
//! diagnostic with a `physicalLocation` (repo-relative URI + start
//! line). Serialization is hand-rolled like `diag::to_json` — stable key
//! order, escaped strings, no dependencies.

use crate::diag::Diag;

/// `(id, shortDescription)` for every stage-2 rule, embedded in the
/// driver so SARIF viewers can label findings without external docs.
const RULES: &[(&str, &str)] = &[
    (
        "panic_cone",
        "Panic-reachability: unwrap/expect/panic!/indexing/unguarded division \
         transitively reachable from a serving entry point",
    ),
    (
        "lock_order",
        "Lock-order: may-hold-while-acquiring cycles and guards held across \
         possibly-blocking callees",
    ),
    (
        "det_taint",
        "Determinism taint: clock/unordered-container/float-reduction values \
         flowing into artifact, packing, or bench-JSON sinks",
    ),
    (
        "unsafe_bounds",
        "Unsafe/bounds audit: unsafe blocks and unchecked accesses without a \
         written safety proof",
    ),
];

/// Serialize `diags` as a SARIF 2.1.0 document.
pub fn to_sarif(diags: &[Diag]) -> String {
    let mut out = String::with_capacity(1024 + diags.len() * 256);
    out.push_str("{\"$schema\":\"https://json.schemastore.org/sarif-2.1.0.json\",");
    out.push_str("\"version\":\"2.1.0\",\"runs\":[{\"tool\":{\"driver\":{");
    out.push_str("\"name\":\"fmq-xtask-analyze\",\"rules\":[");
    for (i, (id, desc)) in RULES.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"id\":\"{}\",\"shortDescription\":{{\"text\":\"{}\"}}}}",
            esc(id),
            esc(desc)
        ));
    }
    out.push_str("]}},\"results\":[");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"ruleId\":\"{}\",\"level\":\"error\",\"message\":{{\"text\":\"{}\"}},\
             \"locations\":[{{\"physicalLocation\":{{\"artifactLocation\":\
             {{\"uri\":\"{}\",\"uriBaseId\":\"SRCROOT\"}},\"region\":\
             {{\"startLine\":{}}}}}}}]}}",
            esc(d.rule),
            esc(&d.msg),
            esc(&d.file),
            d.line.max(1)
        ));
    }
    out.push_str("]}]}");
    out
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sarif_shape_is_valid_and_quotes_are_escaped() {
        let diags = vec![Diag::new(
            "panic_cone",
            "rust/src/a.rs",
            7,
            "`.unwrap()` in serving-reachable `f` (cone: \"x\")",
        )];
        let s = to_sarif(&diags);
        assert!(s.contains("\"version\":\"2.1.0\""));
        assert!(s.contains("\"ruleId\":\"panic_cone\""));
        assert!(s.contains("\"uri\":\"rust/src/a.rs\""));
        assert!(s.contains("\"startLine\":7"));
        assert!(s.contains("\\\"x\\\""), "quotes inside messages must be escaped");
        // four rules declared even when only one fires
        assert_eq!(s.matches("\"shortDescription\"").count(), 4);
    }

    #[test]
    fn empty_findings_still_produce_a_valid_run() {
        let s = to_sarif(&[]);
        assert!(s.contains("\"results\":[]"));
        assert!(s.ends_with("]}]}"));
    }
}
