//! Item-level scan over a lexed file: function definitions with their
//! body extents, enclosing `impl`/`trait` type (for qualified names like
//! `LutModel::velocity_into`), attributes, and test scoping
//! (`#[test]` functions and `#[cfg(test)]` modules are excluded from
//! every rule).
//!
//! This is a single linear pass with a brace-context stack — deliberately
//! far short of a real parser, but exact enough for the four lint rules:
//! bodies are delimited by matching braces, and the only name resolution
//! rules need is "which `fn` items exist, and what type owns them".

use crate::lexer::{Lexed, Tok, TokKind};

/// One `fn` item found in a file.
#[derive(Clone, Debug)]
pub struct FnDef {
    /// Bare name (`velocity_into`).
    pub name: String,
    /// Qualified name: `Type::name` inside `impl`/`trait` blocks, else the
    /// bare name.
    pub qual: String,
    /// 1-based line of the `fn` keyword.
    pub line: u32,
    /// Token index range of the body, **inclusive of both braces**.
    /// `None` for bodyless trait-method signatures.
    pub body: Option<(usize, usize)>,
    /// True if this is test code: `#[test]`, or inside `#[cfg(test)]`.
    pub is_test: bool,
    /// Attribute names seen on the item (`no_alloc`, `inline`, `test`...).
    /// For path attributes (`#[fmq_macros::no_alloc]`) the last segment is
    /// recorded.
    pub attrs: Vec<String>,
}

/// A parsed file: the lexed tokens plus the item index built over them.
#[derive(Debug)]
pub struct ParsedFile {
    pub path: String,
    pub lexed: Lexed,
    pub fns: Vec<FnDef>,
    /// Token index ranges (inclusive braces) of `#[cfg(test)]` modules.
    pub test_ranges: Vec<(usize, usize)>,
}

impl ParsedFile {
    /// Is the token at `idx` inside test-only code (a `#[cfg(test)]`
    /// module or a `#[test]` function body)?
    pub fn in_test_code(&self, idx: usize) -> bool {
        self.test_ranges.iter().any(|&(a, b)| idx > a && idx < b)
            || self.fns.iter().any(|f| {
                f.is_test && f.body.is_some_and(|(a, b)| idx >= a && idx <= b)
            })
    }
}

#[derive(Clone, Copy, PartialEq)]
enum CtxKind {
    /// `impl T { .. }`, `impl Tr for T { .. }`, `trait Tr { .. }`
    TypeBlock,
    /// `mod m { .. }`
    Module,
    /// a fn body (index into `fns`)
    FnBody(usize),
    /// any other brace pair (struct literal, match, block, ...)
    Other,
}

struct Ctx {
    kind: CtxKind,
    /// Type name for TypeBlock, used to qualify member fns.
    type_name: String,
    /// This context (and so everything inside it) is test-only.
    is_test: bool,
    /// Token index of the opening `{`.
    open: usize,
}

/// Scan a lexed file into its `fn` items.
pub fn parse(path: &str, lexed: Lexed) -> ParsedFile {
    let toks = &lexed.toks;
    let n = toks.len();
    let mut fns: Vec<FnDef> = Vec::new();
    let mut test_ranges: Vec<(usize, usize)> = Vec::new();
    let mut stack: Vec<Ctx> = Vec::new();
    // Attributes waiting for the item they decorate.
    let mut pending_attrs: Vec<String> = Vec::new();
    let mut pending_cfg_test = false;
    let mut i = 0usize;

    let in_test = |stack: &[Ctx]| stack.iter().any(|c| c.is_test);
    let type_name = |stack: &[Ctx]| {
        stack
            .iter()
            .rev()
            .find(|c| c.kind == CtxKind::TypeBlock)
            .map(|c| c.type_name.clone())
    };

    while i < n {
        let t = &toks[i];
        if t.is_punct('#') && i + 1 < n && toks[i + 1].is_punct('[') {
            // attribute: collect idents up to the matching ]
            let (names, is_cfg_test, end) = scan_attr(toks, i + 1);
            pending_attrs.extend(names);
            pending_cfg_test |= is_cfg_test;
            i = end;
            continue;
        }
        if t.kind == TokKind::Ident {
            match t.text.as_str() {
                "fn" => {
                    let (def, next) = scan_fn(
                        toks,
                        i,
                        &pending_attrs,
                        pending_cfg_test || in_test(&stack),
                        type_name(&stack),
                    );
                    pending_attrs.clear();
                    pending_cfg_test = false;
                    if let Some((body_open, _)) = def.body {
                        fns.push(def);
                        let idx = fns.len() - 1;
                        stack.push(Ctx {
                            kind: CtxKind::FnBody(idx),
                            type_name: String::new(),
                            is_test: false,
                            open: body_open,
                        });
                        i = body_open + 1;
                    } else {
                        fns.push(def);
                        i = next;
                    }
                    continue;
                }
                "impl" | "trait" => {
                    let (name, open) = scan_type_block_header(toks, i);
                    let is_test = pending_cfg_test;
                    pending_attrs.clear();
                    pending_cfg_test = false;
                    match open {
                        Some(open) => {
                            stack.push(Ctx {
                                kind: CtxKind::TypeBlock,
                                type_name: name,
                                is_test,
                                open,
                            });
                            i = open + 1;
                        }
                        None => i += 1,
                    }
                    continue;
                }
                "mod" => {
                    // `mod name {` opens a module; `mod name;` declares one
                    let is_test = pending_cfg_test;
                    pending_attrs.clear();
                    pending_cfg_test = false;
                    let mut j = i + 1;
                    while j < n && !toks[j].is_punct('{') && !toks[j].is_punct(';') {
                        j += 1;
                    }
                    if j < n && toks[j].is_punct('{') {
                        stack.push(Ctx {
                            kind: CtxKind::Module,
                            type_name: String::new(),
                            is_test,
                            open: j,
                        });
                        i = j + 1;
                    } else {
                        i = j + 1;
                    }
                    continue;
                }
                // items that terminate a pending attribute run
                "struct" | "enum" | "use" | "static" | "const" | "type" | "let"
                | "macro_rules" => {
                    pending_attrs.clear();
                    pending_cfg_test = false;
                }
                _ => {}
            }
        }
        if t.is_punct('{') {
            stack.push(Ctx {
                kind: CtxKind::Other,
                type_name: String::new(),
                is_test: false,
                open: i,
            });
        } else if t.is_punct('}') {
            if let Some(ctx) = stack.pop() {
                if let CtxKind::FnBody(idx) = ctx.kind {
                    if let Some((open, _)) = fns[idx].body {
                        fns[idx].body = Some((open, i));
                    }
                }
                if ctx.is_test {
                    test_ranges.push((ctx.open, i));
                }
            }
        } else if t.is_punct(';') {
            pending_attrs.clear();
            pending_cfg_test = false;
        }
        i += 1;
    }

    ParsedFile {
        path: path.to_string(),
        lexed,
        fns,
        test_ranges,
    }
}

/// Scan an attribute starting at the `[` token; returns (attr names,
/// is-exactly-cfg(test), index past the closing `]`).
fn scan_attr(toks: &[Tok], open: usize) -> (Vec<String>, bool, usize) {
    let n = toks.len();
    let mut depth = 0i32;
    let mut j = open;
    let mut idents: Vec<String> = Vec::new();
    while j < n {
        let t = &toks[j];
        if t.is_punct('[') {
            depth += 1;
        } else if t.is_punct(']') {
            depth -= 1;
            if depth == 0 {
                j += 1;
                break;
            }
        } else if t.kind == TokKind::Ident {
            idents.push(t.text.clone());
        }
        j += 1;
    }
    // `#[cfg(test)]` exactly: idents == [cfg, test]
    let is_cfg_test = idents.len() == 2 && idents[0] == "cfg" && idents[1] == "test";
    // attribute "name" for matching: every ident (so both `no_alloc` and
    // the `fmq_macros` prefix land in attrs; rules match on `no_alloc`)
    (idents, is_cfg_test, j)
}

/// Scan `impl ... {` / `trait Name {`; returns (type name, index of `{`).
fn scan_type_block_header(toks: &[Tok], at: usize) -> (String, Option<usize>) {
    let n = toks.len();
    let mut j = at + 1;
    let mut angle = 0i32;
    let mut in_where = false;
    let mut name = String::new();
    while j < n {
        let t = &toks[j];
        if t.is_punct('<') {
            angle += 1;
        } else if t.is_punct('>') {
            angle = (angle - 1).max(0);
        } else if t.is_punct('{') && angle == 0 {
            return (name, Some(j));
        } else if t.is_punct(';') && angle == 0 {
            return (name, None);
        } else if t.kind == TokKind::Ident && angle == 0 && !in_where {
            match t.text.as_str() {
                // `impl Trait for Type`: the type after `for` wins
                "for" => name.clear(),
                // bounds after `where` never name the implemented type
                "where" => in_where = true,
                "dyn" | "mut" | "unsafe" | "pub" => {}
                _ => {
                    if name.is_empty() {
                        name = t.text.clone();
                    } else if j > 0 && toks[j - 1].is_punct(':') {
                        // path segment `a::B` — keep the last segment
                        name = t.text.clone();
                    }
                }
            }
        }
        j += 1;
    }
    (name, None)
}

/// Scan a `fn` item starting at the `fn` keyword. Returns the def (body
/// filled with `(open, open)` placeholder; the caller patches the close)
/// and the index to resume at when there is no body.
fn scan_fn(
    toks: &[Tok],
    at: usize,
    pending_attrs: &[String],
    is_test_ctx: bool,
    owner: Option<String>,
) -> (FnDef, usize) {
    let n = toks.len();
    let name = toks
        .get(at + 1)
        .filter(|t| t.kind == TokKind::Ident)
        .map(|t| t.text.clone())
        .unwrap_or_default();
    let qual = match &owner {
        Some(t) if !t.is_empty() => format!("{t}::{name}"),
        _ => name.clone(),
    };
    let is_test = is_test_ctx || pending_attrs.iter().any(|a| a == "test");
    let mut def = FnDef {
        name,
        qual,
        line: toks[at].line,
        body: None,
        is_test,
        attrs: pending_attrs.to_vec(),
    };
    // find the body `{` at paren/bracket depth 0, or `;` (no body)
    let mut paren = 0i32;
    let mut bracket = 0i32;
    let mut j = at + 1;
    while j < n {
        let t = &toks[j];
        if t.is_punct('(') {
            paren += 1;
        } else if t.is_punct(')') {
            paren -= 1;
        } else if t.is_punct('[') {
            bracket += 1;
        } else if t.is_punct(']') {
            bracket -= 1;
        } else if paren == 0 && bracket == 0 {
            if t.is_punct('{') {
                def.body = Some((j, j)); // close patched by caller on pop
                return (def, j + 1);
            }
            if t.is_punct(';') {
                return (def, j + 1);
            }
        }
        j += 1;
    }
    (def, n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn parse_src(src: &str) -> ParsedFile {
        parse("test.rs", lex(src))
    }

    #[test]
    fn finds_free_and_impl_fns_with_quals() {
        let src = r#"
            pub fn free_one(x: u32) -> u32 { x + 1 }
            impl Widget {
                pub fn method_a(&self) {}
            }
            impl Render for Widget {
                fn draw(&self) { self.method_a() }
            }
            trait Render {
                fn draw(&self);
                fn clear(&self) { }
            }
        "#;
        let p = parse_src(src);
        let quals: Vec<&str> = p.fns.iter().map(|f| f.qual.as_str()).collect();
        assert_eq!(
            quals,
            vec![
                "free_one",
                "Widget::method_a",
                "Widget::draw",
                "Render::draw",
                "Render::clear"
            ]
        );
        // bodyless trait signature has no body; default method does
        assert!(p.fns[3].body.is_none());
        assert!(p.fns[4].body.is_some());
    }

    #[test]
    fn cfg_test_modules_and_test_fns_are_marked() {
        let src = r#"
            fn prod() {}
            #[cfg(test)]
            mod tests {
                fn helper() {}
                #[test]
                fn check_it() {}
            }
            #[test]
            fn top_level_test() {}
        "#;
        let p = parse_src(src);
        let by_name = |n: &str| p.fns.iter().find(|f| f.name == n).unwrap();
        assert!(!by_name("prod").is_test);
        assert!(by_name("helper").is_test);
        assert!(by_name("check_it").is_test);
        assert!(by_name("top_level_test").is_test);
    }

    #[test]
    fn attrs_are_attached_including_path_attrs() {
        let src = r#"
            #[inline]
            #[fmq_macros::no_alloc]
            pub fn hot(x: &mut [f32]) { x[0] = 0.0; }
        "#;
        let p = parse_src(src);
        assert!(p.fns[0].attrs.iter().any(|a| a == "no_alloc"));
        assert!(p.fns[0].attrs.iter().any(|a| a == "inline"));
    }

    #[test]
    fn body_ranges_cover_matching_braces() {
        let src = "fn a() { if x { y() } } fn b() {}";
        let p = parse_src(src);
        let (o1, c1) = p.fns[0].body.unwrap();
        let (o2, c2) = p.fns[1].body.unwrap();
        assert!(p.lexed.toks[o1].is_punct('{') && p.lexed.toks[c1].is_punct('}'));
        assert!(o2 > c1 && c2 > o2);
        // nested braces stay inside fn a's range
        assert!(c1 - o1 > 4);
    }

    #[test]
    fn array_types_in_signatures_do_not_derail_body_finding() {
        let src = "fn f(x: [u8; 4]) -> [u8; 2] { [x[0], x[1]] }";
        let p = parse_src(src);
        assert!(p.fns[0].body.is_some());
        assert_eq!(p.fns[0].name, "f");
    }
}
