//! A small Rust lexer: source text -> significant tokens.
//!
//! This is *not* a full parser — it is the minimum tokenization the lint
//! rules need: identifiers, punctuation, literals and lifetimes, each
//! carrying a 1-based line number, with comments/strings/chars stripped so
//! rules never match inside them. Building on tokens (instead of regexes
//! over raw text) is what lets rules tell `.unwrap()` from `.unwrap_or()`,
//! skip `vec!` inside a string literal, and track brace depth reliably.
//!
//! Inline suppressions are collected here too: a comment of the form
//! `// fmq-lint: allow(rule_a, rule_b)` records the named rules for its
//! own line, and applies to diagnostics on that line or the next.

/// What kind of token a [`Tok`] is.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `Vec`, `unwrap`, ...).
    Ident,
    /// Single punctuation character (`.`, `{`, `!`, ...).
    Punct,
    /// Number literal (the text is kept but rarely inspected).
    Literal,
    /// Lifetime (`'a`) — kept distinct so `<'a>` never looks like a char.
    Lifetime,
}

/// One significant token with its source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    /// True if this token is the punctuation character `c`.
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.as_bytes()[0] == c as u8
    }

    /// True if this token is the identifier `s`.
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }
}

/// Lexed file: tokens plus the inline markers the two analysis stages
/// honor — `fmq-lint: allow(...)` (stage 1), `fmq-analyze: allow(...) --
/// why` (stage 2, justification required) and `fmq-analyze: safety --
/// proof` (unsafe/bounds audit annotations).
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub allows: Vec<(u32, String)>,
    /// `(line, rule, has_justification)` for `fmq-analyze: allow(...)`.
    pub analyze_allows: Vec<(u32, String, bool)>,
    /// `(line, has_proof)` for `fmq-analyze: safety -- <proof>`.
    pub safety_marks: Vec<(u32, bool)>,
}

impl Lexed {
    /// True if `rule` is suppressed at `line` (marker on the same line or
    /// the line above).
    pub fn allowed(&self, rule: &str, line: u32) -> bool {
        self.allows
            .iter()
            .any(|(l, r)| r == rule && (*l == line || *l + 1 == line))
    }

    /// Stage-2 suppression state for `rule` at `line` (same line or the
    /// line above): `None` = no marker, `Some(has_why)` = marker present,
    /// with or without the required `-- why` justification.
    pub fn analyze_allowed(&self, rule: &str, line: u32) -> Option<bool> {
        self.analyze_allows
            .iter()
            .find(|(l, r, _)| r == rule && (*l == line || *l + 1 == line))
            .map(|&(_, _, why)| why)
    }

    /// Safety-annotation state at `line` (same line or the line above):
    /// `None` = unannotated, `Some(has_proof)` otherwise.
    pub fn safety_at(&self, line: u32) -> Option<bool> {
        self.safety_marks
            .iter()
            .find(|(l, _)| *l == line || *l + 1 == line)
            .map(|&(_, proof)| proof)
    }
}

/// Extract `fmq-lint: allow(a, b)` rule names from a comment body.
fn scan_allow_marker(comment: &str, line: u32, out: &mut Vec<(u32, String)>) {
    let Some(at) = comment.find("fmq-lint:") else {
        return;
    };
    let rest = &comment[at + "fmq-lint:".len()..];
    let rest = rest.trim_start();
    let Some(body) = rest.strip_prefix("allow(") else {
        return;
    };
    let Some(end) = body.find(')') else {
        return;
    };
    for rule in body[..end].split(',') {
        let rule = rule.trim();
        if !rule.is_empty() {
            out.push((line, rule.to_string()));
        }
    }
}

/// Extract `fmq-analyze:` markers (`allow(a, b) -- why` or
/// `safety -- proof`) from a comment body.
fn scan_analyze_marker(
    comment: &str,
    line: u32,
    allows: &mut Vec<(u32, String, bool)>,
    safety: &mut Vec<(u32, bool)>,
) {
    let Some(at) = comment.find("fmq-analyze:") else {
        return;
    };
    let rest = comment[at + "fmq-analyze:".len()..].trim_start();
    if let Some(body) = rest.strip_prefix("allow(") {
        let Some(end) = body.find(')') else {
            return;
        };
        // `-- justification` must follow the close paren and be nonempty
        let tail = body[end + 1..].trim_start();
        let has_why = tail
            .strip_prefix("--")
            .is_some_and(|why| !why.trim().is_empty());
        for rule in body[..end].split(',') {
            let rule = rule.trim();
            if !rule.is_empty() {
                allows.push((line, rule.to_string(), has_why));
            }
        }
    } else if let Some(tail) = rest.strip_prefix("safety") {
        let has_proof = tail
            .trim_start()
            .strip_prefix("--")
            .is_some_and(|p| !p.trim().is_empty());
        safety.push((line, has_proof));
    }
}

/// Tokenize `src`. Never fails: unterminated constructs just consume to
/// end-of-file (the lint is best-effort on malformed input; `cargo build`
/// is the authority on syntax).
pub fn lex(src: &str) -> Lexed {
    let b: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut allows = Vec::new();
    let mut analyze_allows = Vec::new();
    let mut safety_marks = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let n = b.len();

    // Helper closures would need captures; keep it a plain loop.
    while i < n {
        let c = b[i];
        match c {
            '\n' => {
                line += 1;
                i += 1;
            }
            c if c.is_whitespace() => i += 1,
            '/' if i + 1 < n && b[i + 1] == '/' => {
                // line comment (incl. doc comments): consume to newline,
                // harvesting allow-markers
                let start = i + 2;
                let mut j = start;
                while j < n && b[j] != '\n' {
                    j += 1;
                }
                let body: String = b[start..j].iter().collect();
                scan_allow_marker(&body, line, &mut allows);
                scan_analyze_marker(&body, line, &mut analyze_allows, &mut safety_marks);
                i = j;
            }
            '/' if i + 1 < n && b[i + 1] == '*' => {
                // block comment, nesting per Rust rules
                let mut depth = 1u32;
                let mut j = i + 2;
                while j < n && depth > 0 {
                    if b[j] == '\n' {
                        line += 1;
                        j += 1;
                    } else if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                        depth += 1;
                        j += 2;
                    } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                        depth -= 1;
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                i = j;
            }
            '"' => {
                // string literal with escapes
                let mut j = i + 1;
                while j < n {
                    match b[j] {
                        // an escaped newline (line-continuation in a
                        // multi-line string) still ends a source line
                        '\\' => {
                            if j + 1 < n && b[j + 1] == '\n' {
                                line += 1;
                            }
                            j += 2;
                        }
                        '"' => {
                            j += 1;
                            break;
                        }
                        '\n' => {
                            line += 1;
                            j += 1;
                        }
                        _ => j += 1,
                    }
                }
                i = j;
            }
            '\'' => {
                // lifetime or char literal
                let is_lifetime = i + 1 < n
                    && (b[i + 1].is_alphabetic() || b[i + 1] == '_')
                    && {
                        // 'a  -> lifetime unless closed by another quote ('a')
                        let mut j = i + 2;
                        while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                            j += 1;
                        }
                        !(j < n && b[j] == '\'')
                    };
                if is_lifetime {
                    let start = i;
                    let mut j = i + 1;
                    while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                        j += 1;
                    }
                    toks.push(Tok {
                        kind: TokKind::Lifetime,
                        text: b[start..j].iter().collect(),
                        line,
                    });
                    i = j;
                } else {
                    // char literal: '\n', 'x', '\'', '\u{1F600}'
                    let mut j = i + 1;
                    while j < n {
                        match b[j] {
                            '\\' => j += 2,
                            '\'' => {
                                j += 1;
                                break;
                            }
                            '\n' => {
                                line += 1;
                                j += 1;
                            }
                            _ => j += 1,
                        }
                    }
                    i = j;
                }
            }
            'r' | 'b' if is_raw_or_byte_string(&b, i) => {
                i = consume_raw_or_byte_string(&b, i, &mut line);
            }
            c if c.is_alphabetic() || c == '_' => {
                let start = i;
                let mut j = i;
                while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Ident,
                    text: b[start..j].iter().collect(),
                    line,
                });
                i = j;
            }
            c if c.is_ascii_digit() => {
                let start = i;
                let mut j = i;
                while j < n {
                    let d = b[j];
                    if d.is_alphanumeric() || d == '_' {
                        j += 1;
                    } else if d == '.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                        // 1.5 continues the literal; 0..n does not
                        j += 1;
                    } else {
                        break;
                    }
                }
                toks.push(Tok {
                    kind: TokKind::Literal,
                    text: b[start..j].iter().collect(),
                    line,
                });
                i = j;
            }
            _ => {
                toks.push(Tok {
                    kind: TokKind::Punct,
                    text: c.to_string(),
                    line,
                });
                i += 1;
            }
        }
    }
    Lexed {
        toks,
        allows,
        analyze_allows,
        safety_marks,
    }
}

/// Does `b[i..]` start a raw string (`r"`, `r#"`) or byte string (`b"`,
/// `br"`, `br#"`)? Plain identifiers starting with r/b fall through.
fn is_raw_or_byte_string(b: &[char], i: usize) -> bool {
    let n = b.len();
    let mut j = i;
    if b[j] == 'b' {
        j += 1;
        if j < n && b[j] == 'r' {
            j += 1;
        }
    } else if b[j] == 'r' {
        j += 1;
    }
    while j < n && b[j] == '#' {
        j += 1;
    }
    j < n && b[j] == '"' && j > i
}

/// Consume a raw/byte string starting at `i`; returns the index just past
/// it. Tracks newlines into `line`.
fn consume_raw_or_byte_string(b: &[char], i: usize, line: &mut u32) -> usize {
    let n = b.len();
    let mut j = i;
    let mut raw = false;
    if b[j] == 'b' {
        j += 1;
    }
    if j < n && b[j] == 'r' {
        raw = true;
        j += 1;
    }
    let mut hashes = 0usize;
    while j < n && b[j] == '#' {
        hashes += 1;
        j += 1;
    }
    debug_assert!(j < n && b[j] == '"');
    j += 1; // opening quote
    while j < n {
        match b[j] {
            '\n' => {
                *line += 1;
                j += 1;
            }
            '\\' if !raw => j += 2,
            '"' => {
                // need `hashes` trailing #s to close a raw string
                let mut k = j + 1;
                let mut seen = 0usize;
                while k < n && b[k] == '#' && seen < hashes {
                    seen += 1;
                    k += 1;
                }
                if seen == hashes {
                    return k;
                }
                j += 1;
            }
            _ => j += 1,
        }
    }
    j
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_and_comments_are_stripped() {
        let src = r##"
            // vec! in a comment
            /* unwrap() in /* nested */ block */
            let s = "vec![1] .unwrap()";
            let r = r#"format!("x")"#;
            let c = '"';
            real_ident();
        "##;
        let ids = idents(src);
        assert!(ids.contains(&"real_ident".to_string()));
        assert!(!ids.contains(&"vec".to_string()));
        assert!(!ids.contains(&"unwrap".to_string()));
        assert!(!ids.contains(&"format".to_string()));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }";
        let l = lex(src);
        assert!(l.toks.iter().any(|t| t.kind == TokKind::Lifetime));
        // the str idents after the lifetimes must survive
        assert_eq!(idents(src).iter().filter(|s| *s == "str").count(), 3);
    }

    #[test]
    fn allow_markers_are_recorded() {
        let src = "// fmq-lint: allow(panic_safety, no_alloc)\nlet x = v[0];";
        let l = lex(src);
        assert!(l.allowed("panic_safety", 1));
        assert!(l.allowed("panic_safety", 2)); // next line too
        assert!(l.allowed("no_alloc", 2));
        assert!(!l.allowed("determinism", 2));
        assert!(!l.allowed("panic_safety", 3));
    }

    #[test]
    fn analyze_markers_require_justification() {
        let src = "\
// fmq-analyze: allow(panic_cone) -- bounds pinned by caller contract
let x = v[0];
// fmq-analyze: allow(det_taint)
let t = now();
";
        let l = lex(src);
        assert_eq!(l.analyze_allowed("panic_cone", 2), Some(true));
        assert_eq!(l.analyze_allowed("panic_cone", 1), Some(true));
        assert_eq!(l.analyze_allowed("panic_cone", 3), None);
        // marker without `-- why` is recorded as unjustified
        assert_eq!(l.analyze_allowed("det_taint", 4), Some(false));
        assert_eq!(l.analyze_allowed("lock_order", 2), None);
    }

    #[test]
    fn safety_annotations_are_recorded_with_proof_state() {
        let src = "\
// fmq-analyze: safety -- Arc'd buffers are never mutated after publish
unsafe impl Send for X {}
unsafe impl Sync for X {} // fmq-analyze: safety
";
        let l = lex(src);
        assert_eq!(l.safety_at(2), Some(true));
        // annotation without proof text is present but incomplete
        assert_eq!(l.safety_at(3), Some(false));
        assert_eq!(l.safety_at(5), None);
    }

    #[test]
    fn line_numbers_track_newlines() {
        let src = "a\nb\n  c";
        let l = lex(src);
        let lines: Vec<u32> = l.toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 3]);
    }

    #[test]
    fn escaped_newlines_in_strings_still_count_lines() {
        // a `\`-newline continuation inside a string literal ends a
        // source line like any other newline; skipping it as a plain
        // two-byte escape shifted every later diagnostic line
        let src = "let s = \"line one \\\n    continued\";\nafter();";
        let l = lex(src);
        let after = l
            .toks
            .iter()
            .find(|t| t.text == "after")
            .expect("ident survives");
        assert_eq!(after.line, 3);
    }

    #[test]
    fn number_literals_do_not_eat_ranges() {
        let src = "for i in 0..10 { x[i] = 1.5e-3; }";
        let l = lex(src);
        // 0 and 10 are separate literals with two dots between them
        let dots = l.toks.iter().filter(|t| t.is_punct('.')).count();
        assert_eq!(dots, 2);
        assert!(l.toks.iter().any(|t| t.text == "1.5e"));
    }
}
