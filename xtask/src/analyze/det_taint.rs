//! Pass `det_taint` — nondeterminism must not reach artifact sinks.
//!
//! The repo's reproducibility contracts (PRs 2/3/7: byte-stable packed
//! artifacts, replies a pure function of `(model, n, seed, steps)`,
//! conformance-checked `BENCH_*.json` fields) die quietly when a value
//! derived from the wall clock, an unordered container, or an unpinned
//! float reduction flows into a writer. Stage 1 checks *where code
//! lives* (HashMap denied in listed files); this pass checks *where
//! values flow*:
//!
//! - **Seed** taint at clock/thread-id reads (`Instant::now`,
//!   `.elapsed()`, `thread::current`), `HashMap`/`HashSet` usage, and
//!   float reductions (`.sum()`/`.fold()`/`.product()`) inside the
//!   configured reduction scope;
//! - **Propagate** callee -> caller along the whole-workspace call graph
//!   (a function calling a tainted function computes tainted values);
//! - **Deny** when a tainted function *is* a sink or directly calls one
//!   (`StepGrid::new`, `PackedCodes::pack`, the checkpoint/report/bench
//!   writers).
//!
//! Pre-justified sources: `[det_taint] source_allow` fn patterns and
//! `source_allow_paths` file prefixes (the `obs/` registry is a
//! write-only observer — its clock reads feed histograms that never flow
//! back into compute). Site-level suppression:
//! `fmq-analyze: allow(det_taint) -- why` at the source line (kills the
//! seed) or at the sink call line (accepts the flow, e.g. wall-time
//! fields in bench JSON that are explicitly informational).

use std::collections::BTreeSet;

use crate::analyze::{fn_matches, suppressed, AnalyzeConfig};
use crate::callgraph::Graph;
use crate::config::Config;
use crate::diag::Diag;
use crate::lexer::TokKind;
use crate::parse::ParsedFile;
use crate::rules::calls_in;

const RULE: &str = "det_taint";

const UNORDERED: &[&str] = &["HashMap", "HashSet"];
const REDUCTIONS: &[&str] = &["sum", "fold", "product"];

pub fn run(files: &[ParsedFile], graph: &Graph, cfg: &AnalyzeConfig) -> Vec<Diag> {
    let n = graph.nodes.len();
    let mut diags = Vec::new();

    // 1. Seed: per-node direct sources, with a witness description.
    let mut seed = vec![false; n];
    let mut source_desc: Vec<Option<String>> = vec![None; n];
    for u in 0..n {
        let nref = graph.nodes[u];
        let f = &files[nref.file];
        let d = &f.fns[nref.fn_idx];
        let Some((a, b)) = d.body else { continue };
        if fn_matches(&d.qual, &d.name, &cfg.taint_source_allow)
            || Config::path_in(&f.path, &cfg.taint_source_allow_paths)
        {
            continue;
        }
        let toks = &f.lexed.toks;
        let hi = b.min(toks.len().saturating_sub(1));
        let mut note = |line: u32, what: String, diags: &mut Vec<Diag>| {
            if suppressed(f, RULE, line, diags) {
                return;
            }
            seed[u] = true;
            if source_desc[u].is_none() {
                source_desc[u] = Some(format!("{what} at {}:{line}", f.path));
            }
        };
        for call in calls_in(toks, (a, b)) {
            if call.is_macro {
                continue;
            }
            if let Some(q) = &call.qual {
                if cfg.taint_time_paths.iter().any(|p| p == q) {
                    note(call.line, format!("`{q}`"), &mut diags);
                }
            }
            if call.is_method && cfg.taint_time_methods.iter().any(|m| *m == call.name) {
                note(call.line, format!("`.{}()`", call.name), &mut diags);
            }
            if call.is_method
                && REDUCTIONS.contains(&call.name.as_str())
                && Config::path_in(&f.path, &cfg.taint_reduction_scope)
                && !cfg.taint_reduction_allow.iter().any(|x| *x == d.name)
            {
                note(call.line, format!("float `.{}()`", call.name), &mut diags);
            }
        }
        for j in a..=hi {
            let t = &toks[j];
            if t.kind == TokKind::Ident && UNORDERED.contains(&t.text.as_str()) {
                note(t.line, format!("`{}`", t.text), &mut diags);
            }
        }
    }

    // 2. Propagate callee -> caller, with the witness callee recorded.
    let (tainted, via) = graph.propagate_up_witness(&seed);

    // 3. Sinks.
    let mut sink_nodes: BTreeSet<usize> = BTreeSet::new();
    for pat in &cfg.taint_sinks {
        sink_nodes.extend(graph.matching(files, pat));
    }

    // Witness: how `u` became tainted, down to the concrete source.
    let witness = |u: usize| -> String {
        let mut cur = u;
        let mut hops = Vec::new();
        while let Some(nx) = via[cur] {
            hops.push(graph.qual(files, nx).to_string());
            cur = nx;
            if hops.len() > n {
                break;
            }
        }
        let src = source_desc[cur]
            .clone()
            .unwrap_or_else(|| "a nondeterministic source".to_string());
        if hops.is_empty() {
            src
        } else {
            format!("via {}: {src}", hops.join(" -> "))
        }
    };

    // 4a. A sink that is itself tainted.
    for &s in &sink_nodes {
        if !tainted[s] {
            continue;
        }
        let nref = graph.nodes[s];
        let f = &files[nref.file];
        let d = &f.fns[nref.fn_idx];
        if suppressed(f, RULE, d.line, &mut diags) {
            continue;
        }
        diags.push(Diag::new(
            RULE,
            &f.path,
            d.line,
            format!(
                "determinism sink `{}` is itself tainted ({})",
                d.qual,
                witness(s)
            ),
        ));
    }

    // 4b. A tainted function feeding a sink it calls directly.
    let mut reported: BTreeSet<(String, u32, String)> = BTreeSet::new();
    for u in 0..n {
        if !tainted[u] || sink_nodes.contains(&u) {
            continue;
        }
        let nref = graph.nodes[u];
        let f = &files[nref.file];
        let d = &f.fns[nref.fn_idx];
        let Some(body) = d.body else { continue };
        for call in calls_in(&f.lexed.toks, body) {
            for v in graph.resolve(files, u, &call) {
                if !sink_nodes.contains(&v) {
                    continue;
                }
                if suppressed(f, RULE, call.line, &mut diags) {
                    continue;
                }
                let sq = graph.qual(files, v).to_string();
                if !reported.insert((f.path.clone(), call.line, sq.clone())) {
                    continue;
                }
                diags.push(Diag::new(
                    RULE,
                    &f.path,
                    call.line,
                    format!(
                        "determinism-tainted `{}` ({}) calls sink `{sq}`",
                        d.qual,
                        witness(u)
                    ),
                ));
            }
        }
    }
    diags
}
