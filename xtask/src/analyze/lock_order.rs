//! Pass `lock_order` — deadlock freedom over lock classes.
//!
//! Stage 1's `lock_hygiene` checks one scope in one file: a let-bound
//! guard must not sit across a blocking call *in the same function*.
//! This pass generalizes both dimensions:
//!
//! - **Classes.** Every acquisition site (`.lock()`, or the pool's
//!   `.workspace()` slot lease) is assigned a class — the receiver
//!   identifier (`cache.lock()` -> `cache`), or `slot` for workspace
//!   leases. The may-hold-while-acquiring relation over classes forms a
//!   digraph; a cycle means two threads can acquire the same pair of
//!   locks in opposite orders, which is a deadlock under contention, not
//!   a hygiene nit. Classes in `[lock_order] indexed` (per-index
//!   instances like pool slots, where concurrent holders use disjoint
//!   indices by construction) are exempt from self-edges only.
//! - **Transitivity.** While a guard is held, calls are resolved through
//!   the whole-workspace call graph: a callee that may transitively
//!   acquire another class contributes an edge, and a callee that may
//!   transitively block (`send`/`recv`/`join`/...) is reported even when
//!   the blocking call is three frames down in another file.
//!
//! Suppression: `fmq-analyze: allow(lock_order) -- why`.

use std::collections::{BTreeMap, BTreeSet};

use crate::analyze::{suppressed, AnalyzeConfig};
use crate::callgraph::{Graph, NodeId};
use crate::diag::Diag;
use crate::lexer::{Tok, TokKind};
use crate::parse::ParsedFile;
use crate::rules::calls_in;

const RULE: &str = "lock_order";

/// One guard acquisition with the token range it is held over.
struct Held {
    class: String,
    line: u32,
    /// Token range (exclusive of the acquiring statement itself).
    range: (usize, usize),
}

pub fn run(files: &[ParsedFile], graph: &Graph, cfg: &AnalyzeConfig) -> Vec<Diag> {
    let n = graph.nodes.len();

    // Per node: classes acquired anywhere in the body, and whether the
    // body itself contains a blocking call.
    let mut acquires: Vec<BTreeSet<String>> = vec![BTreeSet::new(); n];
    let mut blocks_direct = vec![false; n];
    let mut helds: Vec<Vec<Held>> = Vec::with_capacity(n);
    for u in 0..n {
        let nref = graph.nodes[u];
        let f = &files[nref.file];
        let d = &f.fns[nref.fn_idx];
        let Some((a, b)) = d.body else {
            helds.push(Vec::new());
            continue;
        };
        let toks = &f.lexed.toks;
        let hi = b.min(toks.len().saturating_sub(1));
        let mut hs = Vec::new();
        for j in a..=hi {
            let t = &toks[j];
            if t.kind != TokKind::Ident {
                continue;
            }
            if cfg.lock_blocking.iter().any(|bn| *bn == t.text)
                && j > 0
                && toks[j - 1].is_punct('.')
                && toks.get(j + 1).is_some_and(|nx| nx.is_punct('('))
            {
                blocks_direct[u] = true;
            }
            if cfg.lock_guard_fns.iter().any(|g| *g == t.text)
                && j > 0
                && toks[j - 1].is_punct('.')
                && toks.get(j + 1).is_some_and(|nx| nx.is_punct('('))
            {
                let class = class_of(toks, j);
                acquires[u].insert(class.clone());
                if let Some(range) = held_range(toks, a, j, hi) {
                    hs.push(Held { class, line: t.line, range });
                }
            }
        }
        helds.push(hs);
    }

    // Transitive may-acquire per node (monotone fixpoint, cycle-safe).
    let mut may_acquire = acquires.clone();
    let mut changed = true;
    while changed {
        changed = false;
        for u in 0..n {
            for &v in &graph.callees[u] {
                if v == u {
                    continue;
                }
                let add: Vec<String> = may_acquire[v]
                    .iter()
                    .filter(|c| !may_acquire[u].contains(*c))
                    .cloned()
                    .collect();
                if !add.is_empty() {
                    may_acquire[u].extend(add);
                    changed = true;
                }
            }
        }
    }
    let (may_block, block_via) = graph.propagate_up_witness(&blocks_direct);

    // Walk every held range: build class edges and report blocking.
    let mut edges: BTreeMap<(String, String), (String, u32)> = BTreeMap::new();
    let mut diags = Vec::new();
    let mut reported: BTreeSet<(String, u32, String)> = BTreeSet::new();
    for u in 0..n {
        let nref = graph.nodes[u];
        let f = &files[nref.file];
        let d = &f.fns[nref.fn_idx];
        let toks = &f.lexed.toks;
        for h in &helds[u] {
            for call in calls_in(toks, h.range) {
                if call.is_macro {
                    continue;
                }
                let is_guard = cfg.lock_guard_fns.iter().any(|g| *g == call.name)
                    && call.is_method;
                if is_guard {
                    let dst = class_of(toks, call.at);
                    edges
                        .entry((h.class.clone(), dst))
                        .or_insert((f.path.clone(), call.line));
                    continue;
                }
                if cfg.lock_blocking.iter().any(|bn| *bn == call.name) && call.is_method {
                    if !suppressed(f, RULE, call.line, &mut diags)
                        && reported.insert((f.path.clone(), call.line, call.name.clone()))
                    {
                        diags.push(Diag::new(
                            RULE,
                            &f.path,
                            call.line,
                            format!(
                                "blocking call `{}()` while `{}` guard (line {}) is held \
                                 in `{}`",
                                call.name, h.class, h.line, d.qual
                            ),
                        ));
                    }
                    continue;
                }
                for v in graph.resolve(files, u, &call) {
                    if v == u {
                        continue;
                    }
                    for dst in &may_acquire[v] {
                        edges
                            .entry((h.class.clone(), dst.clone()))
                            .or_insert((f.path.clone(), call.line));
                    }
                    if may_block[v]
                        && !suppressed(f, RULE, call.line, &mut diags)
                        && reported.insert((f.path.clone(), call.line, format!("via {v}")))
                    {
                        let witness = block_chain(files, graph, &block_via, v);
                        diags.push(Diag::new(
                            RULE,
                            &f.path,
                            call.line,
                            format!(
                                "`{}` guard (line {}) held across call to `{}`, which may \
                                 block ({witness}) in `{}`",
                                h.class,
                                h.line,
                                graph.qual(files, v),
                                d.qual
                            ),
                        ));
                    }
                }
            }
        }
    }

    // Cycle detection over the class digraph.
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (src, dst) in edges.keys() {
        if src == dst {
            if !cfg.lock_indexed.iter().any(|c| c == src) {
                let (file, line) = &edges[&(src.clone(), dst.clone())];
                diags.push(Diag::new(
                    RULE,
                    file,
                    *line,
                    format!("acquiring lock class `{src}` while already holding it"),
                ));
            }
            continue;
        }
        adj.entry(src).or_default().push(dst);
    }
    let mut seen_cycles: BTreeSet<Vec<String>> = BTreeSet::new();
    for &start in adj.keys() {
        // DFS from each class; a back edge to the start is a cycle
        let mut stack = vec![(start, 0usize)];
        let mut path = vec![start];
        let mut on_path: BTreeSet<&str> = [start].into();
        while let Some((node, idx)) = stack.pop() {
            let nexts = adj.get(node).map(|v| v.as_slice()).unwrap_or(&[]);
            if idx < nexts.len() {
                stack.push((node, idx + 1));
                let nx = nexts[idx];
                if nx == start {
                    let mut key: Vec<String> = path.iter().map(|s| s.to_string()).collect();
                    key.sort();
                    if seen_cycles.insert(key) {
                        let cyc = path.join(" -> ");
                        let (file, line) = &edges[&(node.to_string(), start.to_string())];
                        diags.push(Diag::new(
                            RULE,
                            file,
                            *line,
                            format!(
                                "lock-order cycle: {cyc} -> {start} — two threads taking \
                                 these locks in opposite orders deadlock under contention"
                            ),
                        ));
                    }
                } else if !on_path.contains(nx) {
                    on_path.insert(nx);
                    path.push(nx);
                    stack.push((nx, 0));
                }
            } else {
                on_path.remove(node);
                path.pop();
            }
        }
    }
    diags
}

/// The lock class of an acquisition site at token `j` (the guard-fn
/// name): `slot` for `.workspace(...)` leases, else the receiver
/// identifier (walking back over `]`/`)` groups and field chains).
fn class_of(toks: &[Tok], j: usize) -> String {
    if toks[j].text == "workspace" {
        return "slot".to_string();
    }
    // j-1 is the `.`; walk back over the receiver's trailing groups
    let mut k = j - 1; // at `.`
    while k > 0 {
        let p = &toks[k - 1];
        if p.is_punct(']') || p.is_punct(')') {
            let (open, close) = if p.is_punct(')') { ('(', ')') } else { ('[', ']') };
            let mut depth = 0i32;
            let mut m = k - 1;
            loop {
                if toks[m].is_punct(close) {
                    depth += 1;
                } else if toks[m].is_punct(open) {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                if m == 0 {
                    break;
                }
                m -= 1;
            }
            k = m;
        } else if p.kind == TokKind::Ident && p.text != "self" {
            return p.text.clone();
        } else if p.kind == TokKind::Ident || p.is_punct('.') || p.is_punct(':') {
            k -= 1;
        } else {
            break;
        }
    }
    "anonymous".to_string()
}

/// The token range a guard obtained at `j` stays live over: for
/// `let`-bound guards, from the end of the `let` statement to the end of
/// the enclosing block or an explicit `drop(guard)`; temporary guards
/// (`m.lock().field = x;`) end within their statement and return `None`
/// (their range cannot contain a resolved call boundary worth walking —
/// chained calls on the guard itself are covered by the caller scan).
fn held_range(toks: &[Tok], body_start: usize, j: usize, hi: usize) -> Option<(usize, usize)> {
    // statement start: nearest `;` / `{` / `}` walking back
    let mut k = j;
    while k > body_start
        && !(toks[k - 1].is_punct(';') || toks[k - 1].is_punct('{') || toks[k - 1].is_punct('}'))
    {
        k -= 1;
    }
    if !toks[k].is_ident("let") {
        return None;
    }
    let mut name_at = k + 1;
    if toks.get(name_at).is_some_and(|t| t.is_ident("mut")) {
        name_at += 1;
    }
    let guard = toks.get(name_at).filter(|t| t.kind == TokKind::Ident)?;
    let guard_name = guard.text.clone();
    // end of the let statement
    let mut m = j;
    while m <= hi && !toks[m].is_punct(';') {
        m += 1;
    }
    let start = m + 1;
    let mut depth = 0i32;
    let mut mm = start;
    while mm <= hi {
        let u = &toks[mm];
        if u.is_punct('{') {
            depth += 1;
        } else if u.is_punct('}') {
            depth -= 1;
            if depth < 0 {
                break;
            }
        } else if u.is_ident("drop")
            && toks.get(mm + 1).is_some_and(|nx| nx.is_punct('('))
            && toks.get(mm + 2).is_some_and(|nx| nx.is_ident(&guard_name))
        {
            break;
        }
        mm += 1;
    }
    (start < mm).then_some((start, mm.saturating_sub(1)))
}

/// Human-readable witness for a may-block verdict: the chain from `v`
/// down to the function containing the blocking call.
fn block_chain(
    files: &[ParsedFile],
    graph: &Graph,
    via: &[Option<NodeId>],
    v: NodeId,
) -> String {
    let mut names = vec![graph.qual(files, v).to_string()];
    let mut cur = v;
    while let Some(nx) = via[cur] {
        names.push(graph.qual(files, nx).to_string());
        cur = nx;
        if names.len() > via.len() {
            break;
        }
    }
    names.join(" -> ")
}
