//! Pass `unsafe_bounds` — every escape from the borrow checker carries
//! its proof.
//!
//! The workspace is `unsafe`-averse by design (the kernels are safe Rust
//! with bounds pinned by shape contracts), so the few sites that do
//! exist must each carry an auditable argument. The pass inventories:
//!
//! - every `unsafe` token outside test code (blocks, `unsafe impl`,
//!   `unsafe fn`);
//! - every call named in `[unsafe_bounds] unchecked`
//!   (`get_unchecked`, `from_raw_parts`, `transmute`, `assume_init`,
//!   ...) — these are the bounds/validity escapes that stay dangerous
//!   even inside an already-annotated `unsafe` block;
//!
//! and requires a `// fmq-analyze: safety -- <proof>` annotation on the
//! same line or the line above. A marker without proof text is itself a
//! finding — the annotation *is* the audit trail.

use std::collections::BTreeSet;

use crate::analyze::AnalyzeConfig;
use crate::diag::Diag;
use crate::lexer::TokKind;
use crate::parse::ParsedFile;

const RULE: &str = "unsafe_bounds";

pub fn run(files: &[ParsedFile], cfg: &AnalyzeConfig) -> Vec<Diag> {
    let mut diags = Vec::new();
    let mut reported: BTreeSet<(String, u32)> = BTreeSet::new();
    for f in files {
        let toks = &f.lexed.toks;
        for (j, t) in toks.iter().enumerate() {
            if t.kind != TokKind::Ident || f.in_test_code(j) {
                continue;
            }
            let site = if t.text == "unsafe" {
                Some("`unsafe`".to_string())
            } else if cfg.unsafe_unchecked.iter().any(|u| *u == t.text)
                && toks.get(j + 1).is_some_and(|nx| {
                    nx.is_punct('(')
                        || (nx.is_punct(':') && toks.get(j + 2).is_some_and(|c| c.is_punct(':')))
                })
            {
                Some(format!("`{}`", t.text))
            } else {
                None
            };
            let Some(what) = site else { continue };
            if !reported.insert((f.path.clone(), t.line)) {
                continue;
            }
            match f.lexed.safety_at(t.line) {
                Some(true) => {}
                Some(false) => diags.push(Diag::new(
                    RULE,
                    &f.path,
                    t.line,
                    format!(
                        "{what} has a `fmq-analyze: safety` annotation without proof \
                         text: append `-- <why this cannot violate memory safety>`"
                    ),
                )),
                None => diags.push(Diag::new(
                    RULE,
                    &f.path,
                    t.line,
                    format!(
                        "{what} without a safety annotation: add \
                         `// fmq-analyze: safety -- <proof>` on this line or the line above"
                    ),
                )),
            }
        }
    }
    diags
}
