//! `cargo xtask analyze` — stage 2 of the static-analysis wall.
//!
//! Stage 1 (`cargo xtask lint`) is syntactic and file-scoped: each rule
//! looks at one file at a time, guided by path lists in `lint.toml`.
//! Stage 2 is *graph*-scoped: it builds the whole-workspace call graph
//! (`callgraph.rs`) and runs four passes whose findings depend on what a
//! function can reach, not on which file it lives in:
//!
//! - [`panic_cone`] — panic-freedom of everything transitively reachable
//!   from the serving entry points (replaces the old three-file list);
//! - [`lock_order`] — the may-hold-while-acquiring graph over lock
//!   classes: cycles (deadlock) and guards held across possibly-blocking
//!   callees, interprocedurally;
//! - [`det_taint`] — nondeterminism sources (clock reads, unordered
//!   containers, float reductions) propagated up the call graph, denied
//!   at artifact/bench/packing sinks;
//! - [`unsafe_bounds`] — every `unsafe` and unchecked-access site must
//!   carry a `// fmq-analyze: safety -- <proof>` annotation.
//!
//! Suppression: `// fmq-analyze: allow(rule) -- why` on the finding's
//! line or the line above. The justification after `--` is mandatory —
//! a bare `allow` is itself reported. Configuration lives in
//! `analyze.toml`; rationale and the full grammar in
//! docs/STATIC_ANALYSIS.md.

pub mod det_taint;
pub mod lock_order;
pub mod panic_cone;
pub mod unsafe_bounds;

use anyhow::{bail, Context, Result};

use crate::callgraph::Graph;
use crate::config::{parse_value, strip_comment};
use crate::diag::{self, Diag};
use crate::parse::ParsedFile;

/// Parsed `analyze.toml`. Field groups mirror the file's sections.
#[derive(Debug, Clone, Default)]
pub struct AnalyzeConfig {
    /// Directories (repo-relative) whose `.rs` files are analyzed.
    pub scan_roots: Vec<String>,

    /// panic_cone: entry-point patterns (`worker_loop`, `Batcher::*`,
    /// `EngineStep::run*`) whose transitive cone is panic-checked.
    pub cone_entries: Vec<String>,
    /// panic_cone: audited kernel fns (patterns) where computed indexing
    /// is the point — bounds are pinned by shape contracts and the
    /// bit-exactness tests, so raw `x[i * k + j]` stays allowed there.
    pub cone_index_audited: Vec<String>,

    /// lock_order: guard-returning method names (`lock`, `workspace`).
    pub lock_guard_fns: Vec<String>,
    /// lock_order: blocking call names (`send`, `recv`, `join`, ...).
    pub lock_blocking: Vec<String>,
    /// lock_order: classes backed by distinct per-index instances
    /// (`slot` — `Pool::workspace(idx)` leases), where a self-edge is
    /// not a deadlock because the indices are disjoint by construction.
    pub lock_indexed: Vec<String>,

    /// det_taint: qualified nondeterminism sources (`Instant::now`).
    pub taint_time_paths: Vec<String>,
    /// det_taint: method-call nondeterminism sources (`elapsed`).
    pub taint_time_methods: Vec<String>,
    /// det_taint: path prefixes where float reductions seed taint.
    pub taint_reduction_scope: Vec<String>,
    /// det_taint: fns whose reductions are order-independent.
    pub taint_reduction_allow: Vec<String>,
    /// det_taint: fn patterns whose *direct* sources are pre-justified
    /// (write-only observers such as `Span::*`).
    pub taint_source_allow: Vec<String>,
    /// det_taint: file prefixes whose direct sources are pre-justified.
    pub taint_source_allow_paths: Vec<String>,
    /// det_taint: sink fn patterns (artifact writers, `StepGrid::new`,
    /// packing) a tainted fn must not be or directly call.
    pub taint_sinks: Vec<String>,

    /// unsafe_bounds: unchecked-access call names that require a safety
    /// annotation (`get_unchecked`, `from_raw_parts`, ...).
    pub unsafe_unchecked: Vec<String>,
}

impl AnalyzeConfig {
    /// Parse an `analyze.toml` document. Unknown sections/keys are hard
    /// errors, mirroring `lint.toml` — a typo must not disable a pass.
    pub fn parse(src: &str) -> Result<AnalyzeConfig> {
        let mut cfg = AnalyzeConfig::default();
        let mut section = String::new();
        let mut lines = src.lines().enumerate().peekable();
        while let Some((ln, raw)) = lines.next() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("analyze.toml:{}: malformed section header", ln + 1);
                };
                section = name.trim().to_string();
                match section.as_str() {
                    "scan" | "panic_cone" | "lock_order" | "det_taint" | "unsafe_bounds" => {}
                    other => bail!("analyze.toml:{}: unknown section [{other}]", ln + 1),
                }
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("analyze.toml:{}: expected `key = value`", ln + 1);
            };
            let key = line[..eq].trim().to_string();
            let mut value = line[eq + 1..].trim().to_string();
            if value.starts_with('[') {
                while !value.contains(']') {
                    let Some((_, more)) = lines.next() else {
                        bail!("analyze.toml:{}: unterminated array for `{key}`", ln + 1);
                    };
                    value.push(' ');
                    value.push_str(strip_comment(more).trim());
                }
            }
            let items = parse_value(&value)
                .with_context(|| format!("analyze.toml:{}: bad value for `{key}`", ln + 1))?;
            let slot = match (section.as_str(), key.as_str()) {
                ("scan", "roots") => &mut cfg.scan_roots,
                ("panic_cone", "entries") => &mut cfg.cone_entries,
                ("panic_cone", "index_audited") => &mut cfg.cone_index_audited,
                ("lock_order", "guard_fns") => &mut cfg.lock_guard_fns,
                ("lock_order", "blocking") => &mut cfg.lock_blocking,
                ("lock_order", "indexed") => &mut cfg.lock_indexed,
                ("det_taint", "time") => &mut cfg.taint_time_paths,
                ("det_taint", "time_methods") => &mut cfg.taint_time_methods,
                ("det_taint", "reduction_scope") => &mut cfg.taint_reduction_scope,
                ("det_taint", "reduction_allow") => &mut cfg.taint_reduction_allow,
                ("det_taint", "source_allow") => &mut cfg.taint_source_allow,
                ("det_taint", "source_allow_paths") => &mut cfg.taint_source_allow_paths,
                ("det_taint", "sinks") => &mut cfg.taint_sinks,
                ("unsafe_bounds", "unchecked") => &mut cfg.unsafe_unchecked,
                (s, k) => bail!("analyze.toml:{}: unknown key `{k}` in [{s}]", ln + 1),
            };
            slot.extend(items);
        }
        Ok(cfg)
    }
}

/// Does a node's qualified name match any pattern in `pats` (exact
/// `Type::name`, bare `name`, or `prefix*` wildcard)?
pub(crate) fn fn_matches(qual: &str, name: &str, pats: &[String]) -> bool {
    pats.iter().any(|p| {
        if let Some(prefix) = p.strip_suffix('*') {
            qual.starts_with(prefix)
        } else if p.contains("::") {
            qual == p
        } else {
            name == p
        }
    })
}

/// Check a stage-2 suppression at `line`: returns `true` (and pushes no
/// finding) when a justified `fmq-analyze: allow(rule)` covers it; an
/// unjustified marker is itself a finding.
pub(crate) fn suppressed(
    f: &ParsedFile,
    rule: &'static str,
    line: u32,
    diags: &mut Vec<Diag>,
) -> bool {
    match f.lexed.analyze_allowed(rule, line) {
        Some(true) => true,
        Some(false) => {
            diags.push(Diag::new(
                rule,
                &f.path,
                line,
                format!(
                    "`fmq-analyze: allow({rule})` without a justification: \
                     append `-- <why this site is safe>`"
                ),
            ));
            true // the site itself is acknowledged; only the missing why is reported
        }
        None => false,
    }
}

/// Analyze in-memory sources (`(repo-relative path, content)` pairs).
/// Pure function of its inputs — the fixture tests drive this directly.
pub fn analyze_sources(files: &[(String, String)], cfg: &AnalyzeConfig) -> Vec<Diag> {
    let parsed: Vec<ParsedFile> = files
        .iter()
        .map(|(path, src)| crate::parse::parse(path, crate::lexer::lex(src)))
        .collect();
    let graph = Graph::build(&parsed);
    let mut diags = Vec::new();
    diags.extend(panic_cone::run(&parsed, &graph, cfg));
    diags.extend(lock_order::run(&parsed, &graph, cfg));
    diags.extend(det_taint::run(&parsed, &graph, cfg));
    diags.extend(unsafe_bounds::run(&parsed, cfg));
    diag::sort(&mut diags);
    // an unjustified `allow` covering several findings on one line would
    // otherwise be reported once per finding
    diags.dedup();
    diags
}
