//! Pass `panic_cone` — panic-freedom of the serving cone.
//!
//! The transitive closure from the `[panic_cone] entries` patterns
//! (`worker_loop`, `handle_conn`, `Batcher::*`, `EngineStep::run*`, the
//! sweep's sample loop) is the code a live request can execute. A panic
//! anywhere in that cone strands every queued client, so inside it the
//! pass denies:
//!
//! - `.unwrap()` / `.expect(...)`;
//! - `panic!` / `unreachable!` / `todo!` / `unimplemented!` and the
//!   `assert!`/`assert_eq!`/`assert_ne!` family (`debug_assert*` stays
//!   allowed — it compiles out of release serving builds);
//! - slice/array indexing `x[i]` and range slicing `x[a..b]`, unless
//!   the index is exactly an enclosing `for`-loop induction variable
//!   (`for i in 0..n { x[i] }` cannot overrun), the index is the full
//!   range `[..]` (never out of bounds), or the function is listed
//!   under `[panic_cone] index_audited` (computed-offset kernels whose
//!   bounds are pinned by shape contracts and bit-exactness tests);
//! - integer division/modulo by a bare variable, unless the divisor is
//!   visibly guarded (`.max(1)` on the divisor or on its `let` binding),
//!   a literal, a `SCREAMING_CASE` named constant, or the division is
//!   float-typed — a float literal on either side, or a cast to
//!   `f32`/`f64` (float division cannot panic).
//!
//! Suppression: `fmq-analyze: allow(panic_cone) -- why`, or the stage-1
//! `fmq-lint: allow(panic_safety)` marker (honored so sites audited
//! under the old file-list rule stay audited, not re-annotated).

use std::collections::BTreeSet;

use crate::analyze::{fn_matches, suppressed, AnalyzeConfig};
use crate::callgraph::Graph;
use crate::diag::Diag;
use crate::lexer::{Tok, TokKind};
use crate::parse::ParsedFile;
use crate::rules::calls_in;

const RULE: &str = "panic_cone";

const PANIC_MACROS: &[&str] = &[
    "panic",
    "unreachable",
    "todo",
    "unimplemented",
    "assert",
    "assert_eq",
    "assert_ne",
];

pub fn run(files: &[ParsedFile], graph: &Graph, cfg: &AnalyzeConfig) -> Vec<Diag> {
    let mut roots = Vec::new();
    for pat in &cfg.cone_entries {
        roots.extend(graph.matching(files, pat));
    }
    let cone = graph.reachable(&roots);

    let mut diags = Vec::new();
    let mut reported: BTreeSet<(String, u32, String)> = BTreeSet::new();
    for (&u, _) in &cone {
        let nref = graph.nodes[u];
        let f = &files[nref.file];
        let d = &f.fns[nref.fn_idx];
        let Some((a, b)) = d.body else { continue };
        let toks = &f.lexed.toks;
        let hi = b.min(toks.len().saturating_sub(1));
        let index_audited = fn_matches(&d.qual, &d.name, &cfg.cone_index_audited);
        let loop_vars = loop_vars_in(toks, a, hi);

        let mut report = |line: u32, what: String, diags: &mut Vec<Diag>| {
            if f.lexed.allowed("panic_safety", line) || suppressed(f, RULE, line, diags) {
                return;
            }
            if !reported.insert((f.path.clone(), line, what.clone())) {
                return;
            }
            let chain = graph.chain(files, &cone, u).join(" -> ");
            diags.push(Diag::new(
                RULE,
                &f.path,
                line,
                format!("{what} in serving-reachable `{}` (cone: {chain})", d.qual),
            ));
        };

        for call in calls_in(toks, (a, b)) {
            if call.is_macro {
                if PANIC_MACROS.contains(&call.name.as_str()) {
                    report(call.line, format!("`{}!`", call.name), &mut diags);
                }
            } else if call.is_method && (call.name == "unwrap" || call.name == "expect") {
                report(call.line, format!("`.{}()`", call.name), &mut diags);
            }
        }

        for j in a..=hi {
            let t = &toks[j];
            if t.is_punct('[') && !index_audited {
                // indexing: `[` preceded by an ident, `)` or `]` is an
                // index expression, not an array literal or type
                let prev_is_place = j > a
                    && (toks[j - 1].kind == TokKind::Ident
                        && !is_keyword(&toks[j - 1].text)
                        || toks[j - 1].is_punct(')')
                        || toks[j - 1].is_punct(']'));
                if prev_is_place
                    && !index_is_full_range(toks, j, hi)
                    && !index_is_pinned_loop_var(toks, j, hi, &loop_vars)
                {
                    report(t.line, "slice indexing".to_string(), &mut diags);
                }
            } else if t.is_punct('/') || t.is_punct('%') {
                // `a / b` with a bare-variable divisor; skip `/=` lhs
                let k = if toks.get(j + 1).is_some_and(|n| n.is_punct('=')) {
                    j + 2
                } else {
                    j + 1
                };
                if !lhs_is_float(toks, a, j) && divisor_may_be_zero(toks, a, k, hi) {
                    let op = if t.is_punct('/') { "division" } else { "modulo" };
                    report(t.line, format!("{op} by unguarded variable"), &mut diags);
                }
            }
        }
    }
    diags
}

fn is_keyword(s: &str) -> bool {
    matches!(
        s,
        "if" | "in" | "as" | "return" | "else" | "match" | "mut" | "ref" | "move" | "break"
    )
}

/// Induction variables of every `for` loop in the body: `for i in ...`
/// and the idents of `for (a, b) in ...` tuple patterns.
fn loop_vars_in(toks: &[Tok], a: usize, hi: usize) -> BTreeSet<String> {
    let mut vars = BTreeSet::new();
    let mut j = a;
    while j <= hi {
        if toks[j].is_ident("for") {
            let mut k = j + 1;
            while k <= hi && !toks[k].is_ident("in") {
                if toks[k].kind == TokKind::Ident
                    && toks[k].text != "mut"
                    && toks[k].text != "_"
                    && toks[k].text != "ref"
                {
                    vars.insert(toks[k].text.clone());
                }
                // a `{` before `in` means this `for` was something else
                if toks[k].is_punct('{') {
                    break;
                }
                k += 1;
            }
            j = k;
        }
        j += 1;
    }
    vars
}

/// Is the index expression `toks[open+1 .. matching ]]` exactly one
/// enclosing-loop induction variable? `for i in 0..n { x[i] }` cannot
/// overrun by construction.
fn index_is_pinned_loop_var(
    toks: &[Tok],
    open: usize,
    hi: usize,
    loop_vars: &BTreeSet<String>,
) -> bool {
    let inner = &toks[open + 1..=hi.min(toks.len() - 1)];
    match inner {
        [v, close, ..] if close.is_punct(']') => {
            v.kind == TokKind::Ident && loop_vars.contains(&v.text)
        }
        _ => false,
    }
}

/// Is the index expression exactly the full range `[..]`? (`..` lexes as
/// two `.` puncts.) A full-range slice can never be out of bounds.
fn index_is_full_range(toks: &[Tok], open: usize, hi: usize) -> bool {
    let lim = hi.min(toks.len() - 1);
    open + 3 <= lim
        && toks[open + 1].is_punct('.')
        && toks[open + 2].is_punct('.')
        && toks[open + 3].is_punct(']')
}

/// Is the expression ending just before the `/` at `j` visibly
/// float-typed? True when the preceding token is a float literal, or a
/// `)` whose balanced group contains one (`(x * 0.5) / n`). Float
/// division cannot panic, whatever the divisor.
fn lhs_is_float(toks: &[Tok], body_start: usize, j: usize) -> bool {
    if j <= body_start {
        return false;
    }
    let mut p = j - 1;
    let t = &toks[p];
    if t.kind == TokKind::Literal && t.text.contains('.') {
        return true;
    }
    if t.is_punct(')') {
        let mut depth = 0i32;
        loop {
            if toks[p].is_punct(')') {
                depth += 1;
            } else if toks[p].is_punct('(') {
                depth -= 1;
                if depth == 0 {
                    return false;
                }
            } else if toks[p].kind == TokKind::Literal && toks[p].text.contains('.') {
                return true;
            }
            if p == body_start {
                break;
            }
            p -= 1;
        }
    }
    false
}

/// Can the divisor starting at `k` be zero at runtime? Scans the primary
/// expression (idents, fields, calls, casts) and clears the site when it
/// sees a `.max(...)` guard, an all-literal divisor, a float literal
/// anywhere in the divisor (the division is float-typed and cannot
/// panic), a `SCREAMING_CASE` named-constant divisor, or a cast to
/// `f32`/`f64`; otherwise looks for a `.max(` on the divisor's own `let`
/// binding earlier in the body.
fn divisor_may_be_zero(toks: &[Tok], body_start: usize, k: usize, hi: usize) -> bool {
    // a bare SCREAMING_CASE ident (not a path/field/call head) is a
    // named constant: constants are compile-time values, not runtime
    // variables that can drift to zero
    if let Some(t0) = toks.get(k) {
        if t0.kind == TokKind::Ident
            && t0.text.len() > 1
            && t0.text.chars().all(|c| !c.is_lowercase())
            && t0.text.chars().any(|c| c.is_alphabetic())
        {
            let nxt = toks.get(k + 1);
            let continues = nxt.is_some_and(|n| n.is_punct('.') || n.is_punct(':') || n.is_punct('('));
            if !continues {
                return false;
            }
        }
    }
    let mut j = k;
    let mut saw_max = false;
    let mut float_cast = false;
    let mut all_literal = true;
    let mut first_ident: Option<&str> = None;
    let mut after_as = false;
    while j <= hi {
        let t = &toks[j];
        match t.kind {
            TokKind::Ident => {
                if t.text == "as" {
                    after_as = true;
                } else if after_as {
                    float_cast = t.text == "f32" || t.text == "f64";
                    after_as = false;
                } else {
                    if t.text == "max" {
                        saw_max = true;
                    }
                    if first_ident.is_none() && t.text != "self" {
                        first_ident = Some(&t.text);
                    }
                    all_literal = false;
                }
            }
            TokKind::Literal => {
                if t.text.contains('.') {
                    return false; // float literal: float division, no panic
                }
            }
            TokKind::Punct => match t.text.as_bytes()[0] {
                b'.' => all_literal = false,
                b':' => all_literal = false, // path segment
                b'(' | b'[' => {
                    // consume the balanced group (args may contain max)
                    let (open, close) = if t.is_punct('(') { ('(', ')') } else { ('[', ']') };
                    let mut depth = 0i32;
                    while j <= hi {
                        if toks[j].is_punct(open) {
                            depth += 1;
                        } else if toks[j].is_punct(close) {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        } else if toks[j].is_ident("max") {
                            saw_max = true;
                        } else if toks[j].kind == TokKind::Literal && toks[j].text.contains('.') {
                            return false; // float literal in the divisor group
                        } else if toks[j].kind == TokKind::Ident {
                            if first_ident.is_none() && toks[j].text != "self" {
                                first_ident = Some(&toks[j].text);
                            }
                            all_literal = false;
                        }
                        j += 1;
                    }
                }
                _ => break, // `;`, `,`, `)`, an operator: divisor ends
            },
            TokKind::Lifetime => break,
        }
        j += 1;
    }
    if saw_max || float_cast || (all_literal && first_ident.is_none()) {
        return false;
    }
    // `let <divisor> = ...` earlier in the body containing `.max(` is a
    // guarded binding (`let hint = steps_hint.max(1); span / hint`)
    if let Some(name) = first_ident {
        let mut m = body_start;
        while m + 2 < k {
            if toks[m].is_ident("let") {
                let mut p = m + 1;
                if toks.get(p).is_some_and(|t| t.is_ident("mut")) {
                    p += 1;
                }
                if toks.get(p).is_some_and(|t| t.is_ident(name)) {
                    let mut q = p + 1;
                    while q < k && !toks[q].is_punct(';') {
                        if toks[q].is_ident("max") {
                            return false;
                        }
                        q += 1;
                    }
                }
            }
            m += 1;
        }
    }
    true
}
