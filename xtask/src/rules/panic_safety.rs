//! Rule `panic_safety` — a panicking worker strands queued clients.
//!
//! In the files listed under `[panic_safety] paths` (the serving stack's
//! request paths and the CLI entry point), non-test code may not:
//!
//! - call `.unwrap()` / `.expect(...)`,
//! - invoke `panic!` / `unreachable!` / `todo!` / `unimplemented!`,
//! - index with `[...]` (slice/array indexing panics on out-of-bounds;
//!   use `get`/`get_mut` and turn a miss into an error reply).
//!
//! Indexing whose bounds are pinned by construction can carry an inline
//! `// fmq-lint: allow(panic_safety)` marker with a justification;
//! `assert!`-style contract checks are left to review (they fail loudly
//! at startup, not per-request).

use crate::config::Config;
use crate::diag::Diag;
use crate::lexer::TokKind;
use crate::parse::ParsedFile;

const RULE: &str = "panic_safety";

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

pub fn run(files: &[ParsedFile], cfg: &Config) -> Vec<Diag> {
    let mut diags = Vec::new();
    for f in files {
        if !Config::path_in(&f.path, &cfg.panic_paths) {
            continue;
        }
        let toks = &f.lexed.toks;
        for d in &f.fns {
            if d.is_test {
                continue;
            }
            let Some((a, b)) = d.body else { continue };
            for j in a..=b.min(toks.len().saturating_sub(1)) {
                let t = &toks[j];
                if f.lexed.allowed(RULE, t.line) {
                    continue;
                }
                match t.kind {
                    TokKind::Ident => {
                        let next_bang = toks.get(j + 1).is_some_and(|n| n.is_punct('!'));
                        let next_paren = toks.get(j + 1).is_some_and(|n| n.is_punct('('));
                        let prev_dot = j > 0 && toks[j - 1].is_punct('.');
                        if next_bang && PANIC_MACROS.contains(&t.text.as_str()) {
                            diags.push(Diag::new(
                                RULE,
                                &f.path,
                                t.line,
                                format!(
                                    "`{}!` in `{}`: a panicking request path \
                                     strands queued clients; return an error \
                                     reply instead",
                                    t.text, d.qual
                                ),
                            ));
                        } else if prev_dot
                            && next_paren
                            && (t.text == "unwrap" || t.text == "expect")
                        {
                            diags.push(Diag::new(
                                RULE,
                                &f.path,
                                t.line,
                                format!(
                                    "`.{}()` in `{}`: convert to an error \
                                     reply (`ok_or_else`/`let ... else`) so \
                                     the worker survives bad input",
                                    t.text, d.qual
                                ),
                            ));
                        }
                    }
                    TokKind::Punct if t.is_punct('[') => {
                        // index expression: `expr[...]` — the `[` directly
                        // follows an ident, `)`, or `]`
                        let indexes = j > a
                            && (toks[j - 1].kind == TokKind::Ident
                                || toks[j - 1].is_punct(')')
                                || toks[j - 1].is_punct(']'));
                        if indexes {
                            diags.push(Diag::new(
                                RULE,
                                &f.path,
                                t.line,
                                format!(
                                    "slice indexing in `{}` panics on \
                                     out-of-bounds; use `get`/`get_mut`, or \
                                     justify with `// fmq-lint: \
                                     allow(panic_safety)` when bounds are \
                                     pinned by construction",
                                    d.qual
                                ),
                            ));
                        }
                    }
                    _ => {}
                }
            }
        }
    }
    diags
}
