//! Rule `lock_hygiene` — no Mutex guard held across a blocking call.
//!
//! In the files listed under `[lock_hygiene] paths`, a `let`-bound guard
//! obtained from a guard-returning method (`.lock()`, or the pool's
//! `.workspace()` slot lease) must not stay live across `send`/`recv`/
//! `join`/`sleep`/other blocking calls: the blocked thread would hold the
//! slot and starve every other worker (or deadlock outright if the peer
//! needs the same lock to make progress).
//!
//! Detection is lexical: from the guard's `let` statement to the end of
//! its enclosing block (or an explicit `drop(guard)`), any call whose
//! name is in `[lock_hygiene] blocking` is flagged. Temporary guards
//! (`m.lock().unwrap().field = x;`) end their borrow within the
//! statement and are not tracked.

use crate::config::Config;
use crate::diag::Diag;
use crate::lexer::TokKind;
use crate::parse::ParsedFile;

const RULE: &str = "lock_hygiene";

pub fn run(files: &[ParsedFile], cfg: &Config) -> Vec<Diag> {
    let mut diags = Vec::new();
    for f in files {
        if !Config::path_in(&f.path, &cfg.lock_paths) {
            continue;
        }
        let toks = &f.lexed.toks;
        for d in &f.fns {
            if d.is_test {
                continue;
            }
            let Some((a, b)) = d.body else { continue };
            let hi = b.min(toks.len().saturating_sub(1));
            for j in a..=hi {
                let t = &toks[j];
                if t.kind != TokKind::Ident
                    || !cfg.lock_guard_fns.iter().any(|g| *g == t.text)
                    || !(j > 0 && toks[j - 1].is_punct('.'))
                    || !toks.get(j + 1).is_some_and(|n| n.is_punct('('))
                {
                    continue;
                }
                // statement start: walk back to the nearest `;`/`{`/`}`
                let mut k = j;
                while k > a
                    && !(toks[k - 1].is_punct(';')
                        || toks[k - 1].is_punct('{')
                        || toks[k - 1].is_punct('}'))
                {
                    k -= 1;
                }
                // only `let`-bound guards outlive their statement
                if !toks[k].is_ident("let") {
                    continue;
                }
                let mut name_at = k + 1;
                if toks.get(name_at).is_some_and(|t| t.is_ident("mut")) {
                    name_at += 1;
                }
                let Some(guard) = toks.get(name_at).filter(|t| t.kind == TokKind::Ident)
                else {
                    continue;
                };
                let guard_name = guard.text.clone();
                let guard_line = guard.line;
                // guard scope: end of the let statement -> end of the
                // enclosing block, or an explicit drop(guard)
                let mut m = j;
                while m <= hi && !toks[m].is_punct(';') {
                    m += 1;
                }
                let mut depth = 0i32;
                let mut mm = m + 1;
                while mm <= hi {
                    let u = &toks[mm];
                    if u.is_punct('{') {
                        depth += 1;
                    } else if u.is_punct('}') {
                        depth -= 1;
                        if depth < 0 {
                            break;
                        }
                    } else if u.is_ident("drop")
                        && toks.get(mm + 1).is_some_and(|n| n.is_punct('('))
                        && toks.get(mm + 2).is_some_and(|n| n.is_ident(&guard_name))
                    {
                        break;
                    } else if u.kind == TokKind::Ident
                        && cfg.lock_blocking.iter().any(|bn| *bn == u.text)
                        && toks.get(mm + 1).is_some_and(|n| n.is_punct('('))
                        && !f.lexed.allowed(RULE, u.line)
                    {
                        diags.push(Diag::new(
                            RULE,
                            &f.path,
                            u.line,
                            format!(
                                "blocking call `{}()` while guard `{}` \
                                 (line {}) is held in `{}`: drop the guard \
                                 first, or move the blocking call out of \
                                 the critical section",
                                u.text, guard_name, guard_line, d.qual
                            ),
                        ));
                        break; // one finding per guard is enough
                    }
                    mm += 1;
                }
            }
        }
    }
    diags
}
