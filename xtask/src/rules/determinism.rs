//! Rule `determinism` — replies and artifacts are pure functions of
//! (model, n, seed, steps); see PR 3's bit-identical serving contract and
//! the packed-artifact byte layout.
//!
//! Two sub-checks:
//!
//! 1. **Ordered containers**: files listed under `[determinism] ordered`
//!    feed packing, tuning keys, artifact serialization, or wire output.
//!    `HashMap`/`HashSet` there iterate in randomized order, so any use
//!    (even a `use` statement) is denied — `BTreeMap`/`BTreeSet` give the
//!    same API with sorted, reproducible iteration.
//! 2. **Float reductions**: within `[determinism] reduction_scope`,
//!    `.sum()` / `.fold()` / `.product()` pin an accumulation order that
//!    silently changes results if iteration order or sharding changes.
//!    Kernels accumulate explicitly (indexed loops); the only allowed
//!    reductions are the functions named in `reduction_allow` (integer
//!    byte/row counts, order-independent by construction).

use crate::config::Config;
use crate::diag::Diag;
use crate::lexer::TokKind;
use crate::parse::ParsedFile;

const RULE: &str = "determinism";

const UNORDERED: &[&str] = &["HashMap", "HashSet"];
const REDUCTIONS: &[&str] = &["sum", "fold", "product"];

pub fn run(files: &[ParsedFile], cfg: &Config) -> Vec<Diag> {
    let mut diags = Vec::new();
    for f in files {
        if Config::path_in(&f.path, &cfg.det_ordered) {
            check_ordered(f, &mut diags);
        }
        if Config::path_in(&f.path, &cfg.det_reduction_scope) {
            check_reductions(f, cfg, &mut diags);
        }
    }
    diags
}

fn check_ordered(f: &ParsedFile, diags: &mut Vec<Diag>) {
    for (j, t) in f.lexed.toks.iter().enumerate() {
        if t.kind != TokKind::Ident || !UNORDERED.contains(&t.text.as_str()) {
            continue;
        }
        if f.in_test_code(j) || f.lexed.allowed(RULE, t.line) {
            continue;
        }
        diags.push(Diag::new(
            RULE,
            &f.path,
            t.line,
            format!(
                "`{}` in ordered-output code: iteration order is randomized \
                 per-process; use `BTree{}` so packed artifacts, tuning keys \
                 and wire output stay reproducible",
                t.text,
                &t.text[4..]
            ),
        ));
    }
}

fn check_reductions(f: &ParsedFile, cfg: &Config, diags: &mut Vec<Diag>) {
    let toks = &f.lexed.toks;
    for d in &f.fns {
        if d.is_test {
            continue;
        }
        let Some((a, b)) = d.body else { continue };
        if cfg.det_reduction_allow.iter().any(|n| *n == d.name) {
            continue;
        }
        for j in a..=b.min(toks.len().saturating_sub(1)) {
            let t = &toks[j];
            if t.kind != TokKind::Ident || !REDUCTIONS.contains(&t.text.as_str()) {
                continue;
            }
            // method position: `.sum(`, `.sum::<T>(`, `.fold(`
            let prev_dot = j > 0 && toks[j - 1].is_punct('.');
            let next_opens = toks.get(j + 1).is_some_and(|n| n.is_punct('('))
                || (toks.get(j + 1).is_some_and(|n| n.is_punct(':'))
                    && toks.get(j + 2).is_some_and(|n| n.is_punct(':')));
            if !prev_dot || !next_opens {
                continue;
            }
            if f.lexed.allowed(RULE, t.line) {
                continue;
            }
            diags.push(Diag::new(
                RULE,
                &f.path,
                t.line,
                format!(
                    "float reduction `.{}()` in `{}` pins an accumulation \
                     order; accumulate explicitly in the kernel, or add the \
                     function to `reduction_allow` if it is order-independent \
                     (integer counts)",
                    t.text, d.qual
                ),
            ));
        }
    }
}
