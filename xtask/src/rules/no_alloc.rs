//! Rule `no_alloc` — alloc-freedom of the velocity hot path (PR 4's
//! zero-allocations-per-ODE-step contract).
//!
//! Functions enter the checked set by carrying `#[fmq_macros::no_alloc]`
//! or by being listed under `[no_alloc] roots` in `lint.toml` (qualified
//! `Type::name` entries disambiguate trait methods from allocating
//! same-name fallbacks; wildcard `Type::*` entries enroll every method of
//! a type — how the `obs::` metric record paths join the set). Inside the
//! set, the rule denies:
//!
//! - forbidden macros (`vec!`, `format!`),
//! - forbidden constructor paths (`Vec::new`, `Box::new`, ...),
//! - forbidden calls (`collect`, `to_vec`, `clone`, ...),
//!
//! and walks the **local call graph** transitively: a call to a local
//! function outside the set is followed into that function's body (all
//! same-name candidates, conservatively), so allocation hidden behind a
//! helper is still caught. Calls whose name belongs to the set are
//! skipped (each member is checked on its own), and `[no_alloc] allow`
//! names mark audited cold paths (cache fill, autotune warm-up) the walk
//! must not enter. Capacity-reusing methods (`with_capacity`, `resize`,
//! `clear`, `push`) are deliberately permitted: the contract is
//! steady-state freedom, which reuse provides.

use std::collections::{BTreeMap, BTreeSet};

use crate::config::Config;
use crate::diag::Diag;
use crate::parse::ParsedFile;
use crate::rules::{calls_in, path_at};

const RULE: &str = "no_alloc";

type DefId = (usize, usize); // (file index, fn index)

pub fn run(files: &[ParsedFile], cfg: &Config) -> Vec<Diag> {
    let mut by_name: BTreeMap<&str, Vec<DefId>> = BTreeMap::new();
    let mut by_qual: BTreeMap<&str, Vec<DefId>> = BTreeMap::new();
    for (fi, f) in files.iter().enumerate() {
        for (di, d) in f.fns.iter().enumerate() {
            if d.is_test || d.body.is_none() {
                continue;
            }
            by_name.entry(&d.name).or_default().push((fi, di));
            by_qual.entry(&d.qual).or_default().push((fi, di));
        }
    }

    // the checked set: annotated or rooted
    let mut check: Vec<DefId> = Vec::new();
    for (fi, f) in files.iter().enumerate() {
        for (di, d) in f.fns.iter().enumerate() {
            if d.is_test || d.body.is_none() {
                continue;
            }
            let rooted = cfg.no_alloc_roots.iter().any(|r| {
                if let Some(ty) = r.strip_suffix("::*") {
                    // wildcard root `Type::*`: every method of the type
                    // joins the checked set (used to enroll whole metric
                    // primitives — obs::{Hist, Counter, Gauge, Span})
                    d.qual
                        .strip_prefix(ty)
                        .is_some_and(|rest| rest.starts_with("::"))
                } else if r.contains("::") {
                    *r == d.qual
                } else {
                    *r == d.name
                }
            });
            if rooted || d.attrs.iter().any(|a| a == "no_alloc") {
                check.push((fi, di));
            }
        }
    }

    let member_names: BTreeSet<&str> = check
        .iter()
        .map(|&(fi, di)| files[fi].fns[di].name.as_str())
        .chain(cfg.no_alloc_allow.iter().map(|s| s.as_str()))
        .collect();
    let forbidden_paths: Vec<(&str, &str)> = cfg
        .no_alloc_forbidden_paths
        .iter()
        .filter_map(|p| p.split_once("::"))
        .collect();

    let mut diags = Vec::new();
    let mut reported: BTreeSet<(String, u32, String)> = BTreeSet::new();
    for &root in &check {
        let mut visited: BTreeSet<DefId> = BTreeSet::new();
        visited.insert(root);
        let mut chain = vec![files[root.0].fns[root.1].qual.clone()];
        scan_def(
            files,
            cfg,
            &by_name,
            &by_qual,
            &member_names,
            &forbidden_paths,
            root,
            &mut visited,
            &mut chain,
            &mut reported,
            &mut diags,
        );
    }
    diags
}

#[allow(clippy::too_many_arguments)]
fn scan_def(
    files: &[ParsedFile],
    cfg: &Config,
    by_name: &BTreeMap<&str, Vec<DefId>>,
    by_qual: &BTreeMap<&str, Vec<DefId>>,
    member_names: &BTreeSet<&str>,
    forbidden_paths: &[(&str, &str)],
    id: DefId,
    visited: &mut BTreeSet<DefId>,
    chain: &mut Vec<String>,
    reported: &mut BTreeSet<(String, u32, String)>,
    diags: &mut Vec<Diag>,
) {
    let f = &files[id.0];
    let d = &f.fns[id.1];
    let Some((a, b)) = d.body else { return };
    let toks = &f.lexed.toks;

    let mut report = |line: u32,
                      what: &str,
                      chain: &[String],
                      reported: &mut BTreeSet<(String, u32, String)>,
                      diags: &mut Vec<Diag>| {
        if f.lexed.allowed(RULE, line) {
            return;
        }
        if !reported.insert((f.path.clone(), line, what.to_string())) {
            return;
        }
        let via = if chain.len() > 1 {
            format!(" (path: {})", chain.join(" -> "))
        } else {
            String::new()
        };
        diags.push(Diag::new(
            RULE,
            &f.path,
            line,
            format!("`{}` uses {what} on the no_alloc hot path{via}", d.qual),
        ));
    };

    // forbidden two-segment constructor paths: Vec::new, Box::new, ...
    for j in a..=b.min(toks.len().saturating_sub(1)) {
        for &(first, last) in forbidden_paths {
            if path_at(toks, j, first, last) {
                report(
                    toks[j].line,
                    &format!("`{first}::{last}`"),
                    chain,
                    reported,
                    diags,
                );
            }
        }
    }

    for call in calls_in(toks, (a, b)) {
        if call.is_macro {
            if cfg.no_alloc_forbidden_macros.iter().any(|m| *m == call.name) {
                report(call.line, &format!("`{}!`", call.name), chain, reported, diags);
            }
            continue;
        }
        if cfg.no_alloc_forbidden_calls.iter().any(|m| *m == call.name) {
            report(call.line, &format!("`{}()`", call.name), chain, reported, diags);
            continue;
        }
        if member_names.contains(call.name.as_str()) {
            // in-set callees are checked on their own; allow-listed
            // callees are audited cold paths
            continue;
        }
        // transitive walk into local definitions; a qualified call that
        // resolves nowhere locally is external (std) and is skipped
        // rather than falling back to every same-named local fn
        let targets: Option<&Vec<DefId>> = match &call.qual {
            Some(q) => by_qual.get(q.as_str()),
            None => by_name.get(call.name.as_str()),
        };
        let Some(targets) = targets else { continue };
        let targets = targets.clone();
        for &t in &targets {
            if !visited.insert(t) {
                continue;
            }
            chain.push(files[t.0].fns[t.1].qual.clone());
            scan_def(
                files,
                cfg,
                by_name,
                by_qual,
                member_names,
                forbidden_paths,
                t,
                visited,
                chain,
                reported,
                diags,
            );
            chain.pop();
        }
    }
}
