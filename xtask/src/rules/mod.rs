//! The four lint rules, plus token-level helpers they share.
//!
//! Each rule is a pure function `(files, config) -> Vec<Diag>`; the
//! driver in `lib.rs` concatenates and sorts the results.

pub mod determinism;
pub mod lock_hygiene;
pub mod no_alloc;
pub mod panic_safety;

use crate::lexer::{Tok, TokKind};

/// A call-looking site inside a token stream: `name(...)`, `.name(...)`,
/// `Type::name(...)`, or `name!(...)`.
#[derive(Debug)]
pub struct Call {
    /// Last path segment (`new` in `Vec::new`).
    pub name: String,
    /// `Type::name` when the call is written as a two-segment path.
    pub qual: Option<String>,
    /// `name!(...)` — macro invocation.
    pub is_macro: bool,
    /// Preceded by `.` (a method call).
    pub is_method: bool,
    /// Token index of the name.
    pub at: usize,
    pub line: u32,
}

/// Keywords that look like `ident (` but are not calls.
const NON_CALL_KEYWORDS: &[&str] = &[
    "if", "while", "match", "return", "for", "in", "fn", "move", "as", "let", "else", "loop",
    "ref", "mut", "pub", "use", "where", "impl", "break", "continue", "unsafe", "dyn",
];

/// Extract call sites from `toks[range]` (an fn body, braces included).
pub fn calls_in(toks: &[Tok], range: (usize, usize)) -> Vec<Call> {
    let (a, b) = range;
    let mut out = Vec::new();
    let mut j = a;
    while j <= b && j < toks.len() {
        let t = &toks[j];
        if t.kind == TokKind::Ident && !NON_CALL_KEYWORDS.contains(&t.text.as_str()) {
            let next = toks.get(j + 1);
            let is_macro = next.is_some_and(|n| n.is_punct('!'));
            // macro bodies still get scanned (their tokens are in the
            // stream); the macro *name* is its own call site
            let opens_call = if is_macro {
                toks.get(j + 2)
                    .is_some_and(|n| n.is_punct('(') || n.is_punct('[') || n.is_punct('{'))
            } else {
                // plain call, or turbofish `name::<T>(`
                next.is_some_and(|n| n.is_punct('('))
                    || (next.is_some_and(|n| n.is_punct(':'))
                        && toks.get(j + 2).is_some_and(|n| n.is_punct(':'))
                        && toks.get(j + 3).is_some_and(|n| n.is_punct('<')))
            };
            if opens_call {
                let prev = j.checked_sub(1).map(|p| &toks[p]);
                let is_method = prev.is_some_and(|p| p.is_punct('.'));
                // two-segment path call: `Type :: name (`
                let qual = if !is_method
                    && j >= 3
                    && toks[j - 1].is_punct(':')
                    && toks[j - 2].is_punct(':')
                    && toks[j - 3].kind == TokKind::Ident
                {
                    Some(format!("{}::{}", toks[j - 3].text, t.text))
                } else {
                    None
                };
                out.push(Call {
                    name: t.text.clone(),
                    qual,
                    is_macro,
                    is_method,
                    at: j,
                    line: t.line,
                });
            }
        }
        j += 1;
    }
    out
}

/// Does `toks[j..]` start the sequence `First :: last` (a two-segment
/// forbidden path like `Vec::new`)?
pub fn path_at(toks: &[Tok], j: usize, first: &str, last: &str) -> bool {
    toks.get(j).is_some_and(|t| t.is_ident(first))
        && toks.get(j + 1).is_some_and(|t| t.is_punct(':'))
        && toks.get(j + 2).is_some_and(|t| t.is_punct(':'))
        && toks.get(j + 3).is_some_and(|t| t.is_ident(last))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    #[test]
    fn extracts_methods_paths_and_macros() {
        let l = lex("fn f() { x.collect(); Vec::new(); vec![1]; g::<u8>(); if x { } }");
        let all = calls_in(&l.toks, (0, l.toks.len() - 1));
        let names: Vec<&str> = all.iter().map(|c| c.name.as_str()).collect();
        assert!(names.contains(&"collect"));
        assert!(names.contains(&"new"));
        assert!(names.contains(&"vec"));
        assert!(names.contains(&"g"));
        assert!(!names.contains(&"if"));
        let newc = all.iter().find(|c| c.name == "new").unwrap();
        assert_eq!(newc.qual.as_deref(), Some("Vec::new"));
        assert!(all.iter().find(|c| c.name == "vec").unwrap().is_macro);
        assert!(all.iter().find(|c| c.name == "collect").unwrap().is_method);
    }

    #[test]
    fn unwrap_or_is_not_unwrap() {
        let l = lex("fn f() { x.unwrap_or(3); }");
        let all = calls_in(&l.toks, (0, l.toks.len() - 1));
        assert!(all.iter().any(|c| c.name == "unwrap_or"));
        assert!(!all.iter().any(|c| c.name == "unwrap"));
    }
}
