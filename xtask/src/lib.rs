//! `xtask` — repo automation for the fmq workspace.
//!
//! Two analysis stages run as subcommands:
//!
//! - `cargo xtask lint` — stage 1, syntactic and file-scoped: enforces
//!   the repo's *unwritten-by-the-compiler* invariants (alloc-freedom of
//!   the hot path, deterministic ordering on artifact paths, panic-free
//!   request handling, lock hygiene) per file, configured by `lint.toml`.
//! - `cargo xtask analyze` — stage 2, graph-scoped: builds the
//!   whole-workspace call graph and checks reachability-dependent
//!   invariants (panic cone from serving entry points, lock-order
//!   deadlock cycles, determinism taint to artifact sinks, unsafe/bounds
//!   audit), configured by `analyze.toml`, with `--sarif` output for CI.
//!
//! Both emit structured `file:line` diagnostics; rationale and the
//! annotation grammar live in `docs/STATIC_ANALYSIS.md`.
//!
//! Design constraint: the linter parses Rust with its own token scanner
//! (`lexer.rs` + `parse.rs`) instead of `syn`, so the workspace keeps a
//! single external dependency (`anyhow`) and builds in offline
//! environments. The scanner is exact about the things the rules need
//! (comments/strings stripped, brace-matched fn bodies, qualified names,
//! `#[cfg(test)]` scoping) and deliberately nothing more; `cargo build`
//! remains the authority on syntax.

pub mod analyze;
pub mod callgraph;
pub mod config;
pub mod diag;
pub mod lexer;
pub mod parse;
pub mod rules;
pub mod sarif;

use std::fs;
use std::path::Path;

use anyhow::{Context, Result};

pub use analyze::{analyze_sources, AnalyzeConfig};
pub use config::Config;
pub use diag::Diag;

/// Lint in-memory sources (`(repo-relative path, content)` pairs).
/// Pure function of its inputs — the fixture tests drive this directly.
pub fn lint_sources(files: &[(String, String)], cfg: &Config) -> Vec<Diag> {
    let parsed: Vec<parse::ParsedFile> = files
        .iter()
        .map(|(path, src)| parse::parse(path, lexer::lex(src)))
        .collect();
    let mut diags = Vec::new();
    diags.extend(rules::no_alloc::run(&parsed, cfg));
    diags.extend(rules::determinism::run(&parsed, cfg));
    diags.extend(rules::panic_safety::run(&parsed, cfg));
    diags.extend(rules::lock_hygiene::run(&parsed, cfg));
    diag::sort(&mut diags);
    diags
}

/// Collect every `.rs` file under `root`-relative `scan_roots`, returning
/// `(repo-relative path, content)` pairs sorted by path (stable output).
pub fn collect_files(root: &Path, scan_roots: &[String]) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for sr in scan_roots {
        let dir = root.join(sr);
        walk(&dir, root, &mut out)
            .with_context(|| format!("scanning `{}`", dir.display()))?;
    }
    out.sort();
    Ok(out)
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<(String, String)>) -> Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)
        .with_context(|| format!("read_dir `{}`", dir.display()))?
        .collect::<std::io::Result<_>>()?;
    entries.sort_by_key(|e| e.path());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, root, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            let rel = p
                .strip_prefix(root)
                .unwrap_or(&p)
                .to_string_lossy()
                .replace('\\', "/");
            let src =
                fs::read_to_string(&p).with_context(|| format!("read `{}`", p.display()))?;
            out.push((rel, src));
        }
    }
    Ok(())
}

/// Find the repo root: the nearest ancestor of `start` containing
/// `lint.toml`.
pub fn find_root(start: &Path) -> Option<std::path::PathBuf> {
    let mut cur = Some(start);
    while let Some(d) = cur {
        if d.join("lint.toml").is_file() {
            return Some(d.to_path_buf());
        }
        cur = d.parent();
    }
    None
}
