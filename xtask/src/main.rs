//! CLI entry point: `cargo xtask <lint|analyze> [...]`.
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/configuration error.

use std::path::PathBuf;
use std::process::ExitCode;

use anyhow::{bail, Context, Result};
use xtask::{analyze_sources, collect_files, find_root, lint_sources, AnalyzeConfig, Config};

const USAGE: &str = "\
usage: cargo xtask <command>

commands:
  lint [--json] [--config <path>]
        stage 1: file-scoped invariant lint over the workspace (see
        lint.toml and docs/STATIC_ANALYSIS.md). --json emits one JSON
        object per line.
  analyze [--json] [--sarif <path>] [--config <path>]
        stage 2: whole-workspace call-graph analysis (panic cone,
        lock order, determinism taint, unsafe audit; see analyze.toml).
        --sarif writes a SARIF 2.1.0 report for CI upload.
";

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(&args) {
        Ok(clean) => {
            if clean {
                ExitCode::SUCCESS
            } else {
                ExitCode::from(1)
            }
        }
        Err(e) => {
            eprintln!("xtask: error: {e:#}");
            ExitCode::from(2)
        }
    }
}

fn run(args: &[String]) -> Result<bool> {
    let Some((cmd, rest)) = args.split_first() else {
        bail!("missing command\n\n{USAGE}");
    };
    match cmd.as_str() {
        "lint" => lint(rest),
        "analyze" => analyze(rest),
        "--help" | "-h" | "help" => {
            println!("{USAGE}");
            Ok(true)
        }
        other => bail!("unknown command `{other}`\n\n{USAGE}"),
    }
}

fn lint(args: &[String]) -> Result<bool> {
    let mut json = false;
    let mut config_path: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--json" => json = true,
            "--config" => {
                let p = it.next().context("--config needs a path")?;
                config_path = Some(PathBuf::from(p));
            }
            other => bail!("unknown argument `{other}`\n\n{USAGE}"),
        }
    }

    let cwd = std::env::current_dir().context("getcwd")?;
    let root = find_root(&cwd)
        .or_else(|| {
            // `cargo xtask` may run from anywhere in the workspace; fall
            // back to the directory containing this crate's manifest
            let m = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
            m.parent().map(|p| p.to_path_buf())
        })
        .context("could not locate repo root (no lint.toml found)")?;
    let cfg_path = config_path.unwrap_or_else(|| root.join("lint.toml"));
    let cfg_src = std::fs::read_to_string(&cfg_path)
        .with_context(|| format!("reading `{}`", cfg_path.display()))?;
    let cfg = Config::parse(&cfg_src)?;

    let files = collect_files(&root, &cfg.scan_roots)?;
    let diags = lint_sources(&files, &cfg);
    for d in &diags {
        if json {
            println!("{}", d.to_json());
        } else {
            println!("{d}");
        }
    }
    if diags.is_empty() {
        eprintln!("xtask lint: clean ({} files, 4 rules)", files.len());
        Ok(true)
    } else {
        eprintln!("xtask lint: {} finding(s)", diags.len());
        Ok(false)
    }
}

fn analyze(args: &[String]) -> Result<bool> {
    let mut json = false;
    let mut sarif_path: Option<PathBuf> = None;
    let mut config_path: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--json" => json = true,
            "--sarif" => {
                let p = it.next().context("--sarif needs a path")?;
                sarif_path = Some(PathBuf::from(p));
            }
            "--config" => {
                let p = it.next().context("--config needs a path")?;
                config_path = Some(PathBuf::from(p));
            }
            other => bail!("unknown argument `{other}`\n\n{USAGE}"),
        }
    }

    let cwd = std::env::current_dir().context("getcwd")?;
    let root = find_root(&cwd)
        .or_else(|| {
            let m = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
            m.parent().map(|p| p.to_path_buf())
        })
        .context("could not locate repo root (no lint.toml found)")?;
    let cfg_path = config_path.unwrap_or_else(|| root.join("analyze.toml"));
    let cfg_src = std::fs::read_to_string(&cfg_path)
        .with_context(|| format!("reading `{}`", cfg_path.display()))?;
    let cfg = AnalyzeConfig::parse(&cfg_src)?;

    let files = collect_files(&root, &cfg.scan_roots)?;
    let diags = analyze_sources(&files, &cfg);
    if let Some(p) = &sarif_path {
        std::fs::write(p, xtask::sarif::to_sarif(&diags))
            .with_context(|| format!("writing `{}`", p.display()))?;
    }
    for d in &diags {
        if json {
            println!("{}", d.to_json());
        } else {
            println!("{d}");
        }
    }
    if diags.is_empty() {
        eprintln!("xtask analyze: clean ({} files, 4 passes)", files.len());
        Ok(true)
    } else {
        eprintln!("xtask analyze: {} finding(s)", diags.len());
        Ok(false)
    }
}
