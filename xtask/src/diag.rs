//! Structured lint diagnostics.

use std::fmt;

/// One finding: rule name, location, human-readable message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diag {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub msg: String,
}

impl Diag {
    pub fn new(rule: &'static str, file: &str, line: u32, msg: impl Into<String>) -> Self {
        Self {
            rule,
            file: file.to_string(),
            line,
            msg: msg.into(),
        }
    }

    /// One-line JSON encoding (stable key order, hand-escaped).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"rule\":\"{}\",\"file\":\"{}\",\"line\":{},\"msg\":\"{}\"}}",
            esc(self.rule),
            esc(&self.file),
            self.line,
            esc(&self.msg)
        )
    }
}

impl fmt::Display for Diag {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Sort findings for stable output: by file, then line, then rule.
pub fn sort(diags: &mut [Diag]) {
    diags.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_json_roundtrip_shape() {
        let d = Diag::new("no_alloc", "rust/src/a.rs", 7, "calls `vec!`");
        assert_eq!(d.to_string(), "rust/src/a.rs:7: [no_alloc] calls `vec!`");
        assert_eq!(
            d.to_json(),
            "{\"rule\":\"no_alloc\",\"file\":\"rust/src/a.rs\",\"line\":7,\"msg\":\"calls `vec!`\"}"
        );
    }

    #[test]
    fn sort_orders_by_file_line_rule() {
        let mut v = vec![
            Diag::new("b", "z.rs", 1, ""),
            Diag::new("a", "a.rs", 9, ""),
            Diag::new("a", "a.rs", 2, ""),
        ];
        sort(&mut v);
        assert_eq!(v[0].file, "a.rs");
        assert_eq!(v[0].line, 2);
        assert_eq!(v[2].file, "z.rs");
    }
}
