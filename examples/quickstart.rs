//! Quickstart: the whole API in ~60 lines.
//!
//!   cargo run --release --offline --example quickstart
//!
//! Initializes a velocity network, quantizes it with every method at 3
//! bits, generates a few samples per variant (through the compiled HLO if
//! `make artifacts` has run, CPU reference otherwise), and prints the
//! fidelity comparison.

use fmq::coordinator::experiment::{pseudo_trained_theta, EvalContext};
use fmq::data::Dataset;
use fmq::metrics::{psnr::batch_psnr, ssim::batch_ssim};
use fmq::model::spec::ModelSpec;
use fmq::quant::{quantize_model, QuantMethod};
use fmq::runtime::{artifacts, ArtifactSet};

fn main() -> anyhow::Result<()> {
    // 1. a model (pseudo-trained here; see e2e_pipeline for real training)
    let spec = ModelSpec::default_spec();
    let theta = pseudo_trained_theta(&spec, Dataset::SynthCeleba);
    println!("model: {} parameters, {} weight tensors", spec.p(), spec.weight_layers().len());

    // 2. a sampling backend: compiled HLO if available
    let art = if artifacts::available(&artifacts::default_dir()) {
        Some(ArtifactSet::load(&artifacts::default_dir())?)
    } else {
        println!("(artifacts missing -> CPU reference backend; run `make artifacts` for the real serving path)");
        None
    };
    let ctx = EvalContext {
        spec: spec.clone(),
        art: art.as_ref(),
        steps: 16,
        n: 16,
        seed: 7,
        engine: None,
    };

    // 3. full-precision reference samples
    let x0 = ctx.start_noise();
    let reference = ctx.generate_fp32(&theta, &x0)?;

    // 4. quantize at 3 bits with each scheme and compare
    println!("\n{:<10} {:>8} {:>9} {:>12} {:>8}", "method", "ssim", "psnr", "w2^2", "ratio");
    for method in QuantMethod::ALL {
        let qm = quantize_model(&spec, &theta, method, 3);
        let imgs = ctx.generate_quant(&qm, &x0)?;
        println!(
            "{:<10} {:>8.4} {:>8.2}dB {:>12.3e} {:>7.1}x",
            method.name(),
            batch_ssim(&reference, &imgs, spec.d),
            batch_psnr(&reference, &imgs, spec.d),
            qm.w2_error(&theta).w2_sq,
            qm.compression_ratio(),
        );
    }
    println!("\nOT (equal-mass) should sit at or above every baseline — the paper's Fig. 3 at one grid point.");
    Ok(())
}
