//! Serving demo + load test: start the TCP server with a quantized model
//! fleet, fire concurrent batched requests, report latency/throughput.
//!
//!   cargo run --release --offline --example serve_quantized
//!
//! Uses the compiled HLO backend when artifacts exist (quantized sampling
//! through the Pallas qmm), CPU reference otherwise.

use std::sync::Arc;
use std::time::{Duration, Instant};

use fmq::coordinator::experiment::pseudo_trained_theta;
use fmq::coordinator::registry::Registry;
use fmq::coordinator::server::{serve, Client, ServerConfig};
use fmq::data::Dataset;
use fmq::model::checkpoint;
use fmq::model::spec::ModelSpec;
use fmq::quant::QuantMethod;
use fmq::runtime::{artifacts, ArtifactSet, SharedArtifacts};

fn main() -> anyhow::Result<()> {
    let spec = ModelSpec::default_spec();
    // prefer the e2e-trained checkpoint when present
    let ckpt = std::path::Path::new("checkpoints/model-synth-mnist.fmq");
    let theta = if ckpt.exists() {
        println!("using trained checkpoint {ckpt:?}");
        checkpoint::load_theta(ckpt, &spec)?
    } else {
        println!("no checkpoint — pseudo-trained weights (run e2e_pipeline first for the real model)");
        pseudo_trained_theta(&spec, Dataset::SynthMnist)
    };

    println!("building variant fleet: fp32 + {{ot,uniform}} x {{2,4,8}} bits ...");
    let registry = Arc::new(Registry::build_fleet(
        &spec,
        &theta,
        &[QuantMethod::Ot, QuantMethod::Uniform],
        &[2, 4, 8],
    ));
    let art = if artifacts::available(&artifacts::default_dir()) {
        println!("backend: compiled HLO (PJRT, Pallas qmm on the quantized path)");
        Some(Arc::new(SharedArtifacts::new(ArtifactSet::load(
            &artifacts::default_dir(),
        )?)))
    } else {
        println!("backend: CPU reference (run `make artifacts` for the real path)");
        None
    };
    let server = serve(
        registry.clone(),
        art,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            steps: 8,
            linger: Duration::from_millis(4),
            engine: None,
            ..Default::default()
        },
    )?;
    let addr = server.addr.to_string();
    println!("server on {addr}; models: {:?}", registry.names());

    // ---- load test: concurrent clients against the ot4 variant ---------
    let clients = 8;
    let reqs_per_client = 4;
    let n_per_req = 2;
    println!(
        "\nload test: {clients} clients x {reqs_per_client} requests x {n_per_req} samples (model ot4)"
    );
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut cli = Client::connect(&addr)?;
            let mut lats = Vec::new();
            for r in 0..reqs_per_client {
                let t = Instant::now();
                let imgs = cli.generate("ot4", n_per_req, (c * 100 + r) as u64)?;
                assert_eq!(imgs.len(), n_per_req * 768);
                lats.push(t.elapsed().as_secs_f64());
            }
            Ok(lats)
        }));
    }
    let mut lats: Vec<f64> = Vec::new();
    for h in handles {
        lats.extend(h.join().unwrap()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(f64::total_cmp);
    let total_samples = clients * reqs_per_client * n_per_req;
    println!(
        "done: {total_samples} samples in {wall:.2}s -> {:.1} samples/s",
        total_samples as f64 / wall
    );
    println!(
        "latency p50 {:.1}ms  p95 {:.1}ms  max {:.1}ms",
        lats[lats.len() / 2] * 1e3,
        lats[(lats.len() as f64 * 0.95) as usize] * 1e3,
        lats.last().unwrap() * 1e3
    );
    println!(
        "server stats: {} requests, {} batches ({:.2} requests/batch — dynamic batching at work)",
        server.stats.requests.get(),
        server.stats.batches.get(),
        server.stats.requests.get() as f64 / server.stats.batches.get().max(1) as f64
    );

    // ---- exact-n slicing + determinism --------------------------------
    // a 40-sample request exceeds the model batch (16): the server slices
    // it across super-batches and reassembles exactly 40 rows, and the
    // reply is a pure function of (model, n, seed) — rerunning it, even
    // co-batched with other traffic, is bit-identical
    let mut cli = Client::connect(&addr)?;
    let a = cli.generate("ot4", 40, 4242)?;
    let b = cli.generate("ot4", 40, 4242)?;
    assert_eq!(a.len(), 40 * 768);
    assert_eq!(a, b);
    println!("\nexact-n: 40 samples (model batch 16) sliced + reassembled, bit-deterministic");

    // ---- encode: reverse-ODE latent extraction (paper Fig. 4) ---------
    let imgs = cli.generate("ot4", 2, 7)?;
    let latents = cli.encode("ot4", &imgs)?;
    let var = latents.iter().map(|v| (v * v) as f64).sum::<f64>() / latents.len() as f64;
    let enc_n = imgs.len() / 768;
    println!("encode: {enc_n} images -> latents, E[z^2] = {var:.3} (~1 when stable)");

    // ---- stats op ------------------------------------------------------
    let s = cli.stats()?;
    println!(
        "stats op: requests={} batches={} samples={} encodes={} queue_depth={}",
        s.req("requests")?.as_f64().unwrap_or(0.0),
        s.req("batches")?.as_f64().unwrap_or(0.0),
        s.req("samples")?.as_f64().unwrap_or(0.0),
        s.req("encodes")?.as_f64().unwrap_or(0.0),
        s.req("queue_depth")?.as_f64().unwrap_or(0.0),
    );
    // memory: packed model bytes resident in the engines vs the scratch
    // high-water of the per-worker arenas (steady after the first batch
    // of each step grid — the hot path reuses, never reallocates)
    println!(
        "memory: resident {:.1} KB packed model, workspace high-water {:.1} KB scratch",
        s.req("resident_bytes")?.as_f64().unwrap_or(0.0) / 1024.0,
        s.req("workspace_bytes")?.as_f64().unwrap_or(0.0) / 1024.0,
    );

    server.stop();
    println!("server stopped cleanly");
    Ok(())
}
