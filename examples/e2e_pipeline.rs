//! End-to-end driver (DESIGN.md experiment E2E) — the full system on a
//! real workload, proving all three layers compose:
//!
//!   1. TRAIN the 2.4M-param velocity network on synth-mnist for several
//!      hundred steps through the AOT `train_step` artifact (rust owns the
//!      loop; loss curve logged).
//!   2. QUANTIZE the trained checkpoint with all four methods at
//!      b ∈ {2,3,4,6,8}.
//!   3. GENERATE paired samples (same start noise) fp32-vs-quantized
//!      through the `qsample_step` artifact (Pallas qmm inside) and score
//!      SSIM / PSNR / latent stability.
//!   4. Report the Fig. 3/4-shaped tables + wall-clock numbers.
//!
//!   cargo run --release --offline --example e2e_pipeline
//!
//! Requires `make artifacts`. Results are recorded in EXPERIMENTS.md §E2E.

use std::path::PathBuf;

use fmq::coordinator::experiment::EvalContext;
use fmq::coordinator::report;
use fmq::data::Dataset;
use fmq::flow::train::{loss_improvement, train, TrainConfig};
use fmq::model::checkpoint;
use fmq::model::spec::ModelSpec;
use fmq::quant::QuantMethod;
use fmq::runtime::{artifacts, ArtifactSet};

fn main() -> anyhow::Result<()> {
    let dir = artifacts::default_dir();
    if !artifacts::available(&dir) {
        anyhow::bail!("e2e_pipeline needs artifacts — run `make artifacts` first");
    }
    let art = ArtifactSet::load(&dir)?;
    let spec = ModelSpec::default_spec();
    let dataset = Dataset::SynthMnist;
    let steps: usize = std::env::var("FMQ_E2E_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);

    // ---- 1. train ------------------------------------------------------
    println!("== [1/4] training on {} for {steps} steps (AOT train_step) ==", dataset.name());
    let cfg = TrainConfig {
        steps,
        lr: 1e-3,
        seed: 42,
        log_every: 50,
    };
    let res = train(&art, dataset, &cfg)?;
    let first = res.losses.first().unwrap().1;
    let last = res.losses.last().unwrap().1;
    println!(
        "loss {first:.2} -> {last:.2} (x{:.2} improvement) in {:.1}s ({:.2} steps/s)",
        loss_improvement(&res.losses),
        res.wall_s,
        steps as f64 / res.wall_s
    );
    assert!(
        loss_improvement(&res.losses) > 1.2,
        "training failed to reduce the loss"
    );
    std::fs::create_dir_all("checkpoints")?;
    let ckpt = PathBuf::from(format!("checkpoints/model-{}.fmq", dataset.name()));
    checkpoint::save_theta(&ckpt, &res.theta, vec![])?;
    // loss curve CSV for EXPERIMENTS.md
    std::fs::create_dir_all("results")?;
    report::write_csv(
        &PathBuf::from("results/e2e_loss_curve.csv"),
        "step,loss",
        &res
            .losses
            .iter()
            .map(|(s, l)| format!("{s},{l}"))
            .collect::<Vec<_>>(),
    )?;

    // ---- 2+3. quantize + paired generation ------------------------------
    println!("\n== [2-3/4] quantize + paired generation (Pallas qmm via PJRT) ==");
    let ctx = EvalContext {
        spec: spec.clone(),
        art: Some(&art),
        steps: 32,
        n: 32,
        seed: 7,
        engine: None,
    };
    let methods = QuantMethod::ALL;
    let bits = [2u8, 3, 4, 6, 8];
    let t0 = std::time::Instant::now();
    let fid_points = ctx.fidelity_sweep(dataset, &res.theta, &methods, &bits)?;
    println!("fidelity sweep ({} points) in {:.1}s", fid_points.len(), t0.elapsed().as_secs_f64());

    println!("\nFig.3-shaped table (SSIM | PSNR vs fp32 reference):");
    print!("{:>8} |", "bits");
    for m in methods {
        print!(" {:>16} |", m.name());
    }
    println!();
    for &b in &bits {
        print!("{b:>8} |");
        for m in methods {
            let p = fid_points
                .iter()
                .find(|p| p.method == m && p.bits == b)
                .unwrap();
            print!(" {:>6.4} / {:>5.1}dB |", p.ssim, p.psnr);
        }
        println!();
    }
    report::fidelity_csv(&PathBuf::from("results/e2e_fig3.csv"), &fid_points)?;

    // ---- 4. latent stability -------------------------------------------
    println!("\n== [4/4] latent stability (reverse ODE, Fig.4-shaped) ==");
    let lat_points = ctx.latent_sweep(dataset, &res.theta, &methods, &[2, 4, 8])?;
    println!("{:>8} {:>9} {:>12} {:>12}", "method", "bits", "var_std", "fp32 base");
    for p in &lat_points {
        println!(
            "{:>8} {:>9} {:>12.4} {:>12.4}",
            p.method.name(),
            p.bits,
            p.stats.var_std,
            p.baseline_var_std
        );
    }
    report::latent_csv(&PathBuf::from("results/e2e_fig4.csv"), &lat_points)?;

    // ---- headline check --------------------------------------------------
    let ot3 = fid_points
        .iter()
        .find(|p| p.method == QuantMethod::Ot && p.bits == 3)
        .unwrap();
    let un3 = fid_points
        .iter()
        .find(|p| p.method == QuantMethod::Uniform && p.bits == 3)
        .unwrap();
    let lg3 = fid_points
        .iter()
        .find(|p| p.method == QuantMethod::Log2 && p.bits == 3)
        .unwrap();
    println!(
        "\nheadline @3 bits: OT ssim {:.4} vs uniform {:.4} vs log2 {:.4}",
        ot3.ssim, un3.ssim, lg3.ssim
    );
    println!(
        "compression at 3 bits: x{:.1} ({} -> {} KB)",
        ot3.compression,
        spec.p() * 4 / 1024,
        (spec.p() * 4) / 1024 / ot3.compression as usize
    );
    println!("\ncsv outputs: results/e2e_loss_curve.csv, results/e2e_fig3.csv, results/e2e_fig4.csv");
    println!("checkpoint:  {ckpt:?} (reused by `fmq sweep/latent/grid`)");
    Ok(())
}
