//! The paper's theory, evaluated end to end on a concrete model:
//! empirical Lipschitz constants (Assumptions 1-A/B/C) -> front constants
//! C_U / C_E -> Theorem 3/6 FID-bound curves -> ρ(b) -> Corollary 13.1/13.2
//! bit budgets, plus the α(f_W) estimators against their closed forms.
//!
//!   cargo run --release --offline --example theory_bounds

use fmq::coordinator::experiment::pseudo_trained_theta;
use fmq::data::Dataset;
use fmq::flow::cpu_ref::CpuOracle;
use fmq::metrics::features::FeatureNet;
use fmq::model::spec::ModelSpec;
use fmq::stats::dist::{alpha_gaussian, alpha_laplace};
use fmq::theory::alpha::{alpha_spacing, spacing_for};
use fmq::theory::bounds::BoundInputs;
use fmq::theory::lipschitz::{estimate_l_theta_2, estimate_l_theta_inf, estimate_l_x};
use fmq::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let spec = ModelSpec::default_spec();
    let theta = pseudo_trained_theta(&spec, Dataset::SynthCeleba);

    // ---- closed-form alpha table (paper Eq. 18 + Laplace paragraph) ----
    println!("== alpha(f_W) closed forms vs estimators ==");
    let sigma = 0.05f64;
    println!(
        "gaussian sigma={sigma}: closed {:.4} (alpha^3 = {:.2} sigma^2; paper quotes 32.8)",
        alpha_gaussian(sigma),
        alpha_gaussian(sigma).powi(3) / (sigma * sigma)
    );
    let beta = sigma / std::f64::consts::SQRT_2;
    println!(
        "laplace  sigma={sigma}: closed {:.4} (alpha^3 = {:.1} sigma^2; paper quotes 54)",
        alpha_laplace(beta),
        alpha_laplace(beta).powi(3) / (sigma * sigma)
    );

    // per-layer empirical alpha on the model
    println!("\n== per-layer empirical alpha (order-statistics estimator) ==");
    for l in spec.weight_layers() {
        let w = theta.layer(&spec, &l.name);
        let a = alpha_spacing(w, spacing_for(w.len()));
        let r = fmq::quant::uniform::symmetric_range(w) as f64;
        println!(
            "  {:8}  alpha {:.4}   R {:.3}   alpha^3/R^2 {:.3} (paper band 0.3-0.5 for ~8-10 sigma clips)",
            l.name,
            a,
            r,
            a.powi(3) / (r * r)
        );
    }

    // ---- empirical Lipschitz constants ---------------------------------
    println!("\n== empirical Lipschitz constants (finite differences) ==");
    let mut rng = Pcg64::seed(13);
    let mut oracle = CpuOracle {
        spec: &spec,
        theta: &theta,
    };
    let l_x = estimate_l_x(&mut oracle, &mut rng, 12, 1e-2);
    println!("L_x       (Assumption 1-A) ~= {l_x:.3}");
    let l_t2 = estimate_l_theta_2(&mut oracle, &mut rng, 4, 1e-3);
    println!("L_theta2  (Assumption 1-C) ~= {l_t2:.3}");
    let l_tinf = estimate_l_theta_inf(&mut oracle, &mut rng, 3, 1e-4);
    println!("L_thetaI  (Assumption 1-B) ~= {l_tinf:.3}");
    let net = FeatureNet::standard(spec.d);
    let l_phi = net.lipschitz_bound();
    println!("L_phi     (Assumption 1-D, provable bound) = {l_phi:.3}");

    // ---- bound curves + rho + budgets ----------------------------------
    // alpha over the whole parameter vector (size-weighted layers)
    let mut alpha_model = 0.0;
    let mut total = 0usize;
    for l in spec.weight_layers() {
        let w = theta.layer(&spec, &l.name);
        alpha_model += alpha_spacing(w, spacing_for(w.len())) * w.len() as f64;
        total += w.len();
    }
    alpha_model /= total as f64;
    let r_model = spec
        .weight_layers()
        .iter()
        .map(|l| fmq::quant::uniform::symmetric_range(theta.layer(&spec, &l.name)) as f64)
        .fold(0.0f64, f64::max);
    // the paper's Eq.-17 premise: L_theta2 * sqrt(p) ~= L_thetaInf * R.
    // report how far the measured constants actually are from it.
    let lhs = l_t2 * (spec.pw() as f64).sqrt();
    let rhs = l_tinf * r_model;
    println!(
        "\npaper premise check: L_th2*sqrt(p) = {lhs:.1} vs L_thInf*R = {rhs:.1}  (ratio {:.2})",
        lhs / rhs
    );
    println!("(the premise is what makes rho collapse to the histogram term; the gap");
    println!(" above propagates straight into rho — see DESIGN.md §paper-errata)");

    let b = BoundInputs {
        l_x,
        l_theta_inf: l_tinf,
        l_theta_2: l_t2,
        l_phi,
        t: 1.0,
        r: r_model,
        p: spec.pw() as f64,
        alpha: alpha_model,
    };
    println!("\n== Theorem 3/6 FID-bound curves (measured constants) ==");
    println!("{:>6} {:>14} {:>14} {:>10}", "bits", "C_U 2^-2b", "C_E 2^-2b", "ratio");
    for bits in 2..=8u8 {
        let u = b.fid_bound_uniform(bits);
        let e = b.fid_bound_ot(bits);
        println!("{bits:>6} {u:>14.4e} {e:>14.4e} {:>10.4}", e / u);
    }
    println!("measured rho = C_E/C_U = {:.4e}", b.rho());

    // analytic table under the paper's own premise (enforced), where the
    // provable-advantage story is exact
    let ba = BoundInputs::paper_defaults(0.05, 10.0);
    println!("\n== same tables under the paper's premise (enforced analytically) ==");
    println!("rho = alpha^3/12 = {:.4e} (<1: {})", ba.rho(), ba.rho() < 1.0);
    println!("{:>12} {:>14} {:>10} {:>10}", "FID budget", "uniform bits", "OT bits", "headroom");
    for exp in 0..=4 {
        let delta = ba.c_uniform() * 10f64.powi(-exp);
        let bu = ba.bit_budget(delta, false);
        let bo = ba.bit_budget(delta, true);
        println!("{delta:>12.3e} {bu:>14} {bo:>10} {:>10}", bu as i32 - bo as i32);
    }
    println!(
        "\nCorollary 13.1 headroom under the premise: {} bits (paper claims ~2)",
        ((1.0 / ba.rho()).log2() / 2.0).floor()
    );
    Ok(())
}
